//! Minimal JSON parser/writer (no serde in the offline image).
//!
//! Covers the full JSON grammar we produce from `python/compile/aot.py`
//! (objects, arrays, strings with escapes, numbers, bools, null). Used for
//! `manifest.json`, `meta.json`, config files and experiment output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization
/// is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&src)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (wanted key '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn int(&self) -> Result<i64> {
        Ok(self.num()? as i64)
    }

    pub fn usize(&self) -> Result<usize> {
        let n = self.num()?;
        if n < 0.0 {
            bail!("negative where usize expected: {n}");
        }
        Ok(n as usize)
    }

    pub fn boolean(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Shape-style arrays: [4, 128] → vec![4, 128].
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|v| v.usize()).collect()
    }

    // -- construction helpers ----------------------------------------------

    pub fn object(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // -- serialization -----------------------------------------------------

    pub fn dumps(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |o: &mut String, n: usize| {
            if pretty {
                o.push('\n');
                for _ in 0..n {
                    o.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    x.write(out, indent, false); // arrays stay inline
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i,
                  self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()
            .map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code)
                                .unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // continue collecting multibyte utf-8 as-is
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        // find the full utf-8 char
                        let start = self.i - 1;
                        let mut end = self.i;
                        while end < self.b.len()
                            && (self.b[end] & 0xC0) == 0x80
                        {
                            end += 1;
                        }
                        s.push_str(std::str::from_utf8(
                            &self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, got '{}'",
                           self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got '{}'",
                           self.i, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "3e2", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.dumps()).unwrap();
            assert_eq!(v, v2, "{src}");
        }
    }

    #[test]
    fn nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny"}], "c": {"d": null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().arr().unwrap()[2]
                .get("b").unwrap().str().unwrap(),
            "x\ny");
        let v2 = Json::parse(&v.dumps()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café — ok""#).unwrap();
        assert_eq!(v.str().unwrap(), "café — ok");
        let raw = Json::parse("\"emoji \u{1F600} done\"").unwrap();
        assert_eq!(raw.str().unwrap(), "emoji \u{1F600} done");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn shape_vec() {
        let v = Json::parse("[4, 128]").unwrap();
        assert_eq!(v.usize_vec().unwrap(), vec![4, 128]);
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::object(vec![
            ("name", Json::Str("rap".into())),
            ("n", Json::Num(12.0)),
            ("xs", Json::nums(&[1.0, 2.5])),
        ]);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }
}

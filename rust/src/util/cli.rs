//! Tiny CLI argument parser (no clap in the offline image).
//!
//! Grammar: `rap <subcommand> [positional...] [--flag] [--key value]`.
//! `--key=value` is also accepted.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["serve", "--budget", "0.8", "--verbose",
                        "--model=rap-small", "extra"]);
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("budget"), Some("0.8"));
        assert_eq!(a.get("model"), Some("rap-small"));
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["--n", "42", "--x", "1.5"]);
        assert_eq!(a.usize_or("n", 0).unwrap(), 42);
        assert_eq!(a.f64_or("x", 0.0).unwrap(), 1.5);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert!(a.usize_or("x", 0).is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "v"]);
        assert_eq!(a.get("a"), Some("true"));
        assert_eq!(a.get("b"), Some("v"));
    }
}

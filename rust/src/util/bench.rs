//! Mini benchmark harness (criterion is not available offline).
//!
//! Gives `cargo bench` (with `harness = false`) warmup, repeated timed
//! iterations, and mean/p50/p95 reporting. Deliberately tiny, but enough
//! to compare hot-path changes during the §Perf iteration loop.
//!
//! This module is the sanctioned wall-clock reader (`rap lint` exempts
//! it by path), so the clippy `disallowed_methods` gate is lifted for
//! the whole file rather than per call.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use super::stats::percentile;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name, self.iters, fmt_ns(self.mean_ns), fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns), fmt_ns(self.min_ns))
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` repeatedly: a few warmup calls, then timed iterations until
/// `target_secs` of measurement or `max_iters`, whichever first.
pub fn bench<F: FnMut()>(name: &str, target_secs: f64, max_iters: usize,
                         mut f: F) -> BenchResult {
    // Warmup: at least 2 calls, at most ~10% of budget.
    let warm_start = Instant::now();
    let mut warm = 0;
    while warm < 2
        || (warm_start.elapsed().as_secs_f64() < target_secs * 0.1
            && warm < 10)
    {
        f();
        warm += 1;
    }

    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < target_secs
        && samples.len() < max_iters
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    if samples.is_empty() {
        samples.push(f64::NAN);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: mean,
        p50_ns: percentile(&samples, 50.0),
        p95_ns: percentile(&samples, 95.0),
        min_ns: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// is stable but this keeps call sites uniform).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("noop-loop", 0.05, 10_000, || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(r.iters >= 1);
        assert!(r.mean_ns > 0.0);
        assert!(r.p95_ns >= r.p50_ns);
    }
}

//! Small statistics helpers: online summaries, percentiles, histograms.

/// Streaming summary (count/mean/min/max + variance via Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: u64,
    pub mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY,
                  max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile over a sample (linear interpolation, p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { f64::NAN } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Fixed-bin histogram for trace/figure output.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        Histogram { lo, hi, bins: vec![0; nbins], underflow: 0, overflow: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let b = ((x - self.lo) / (self.hi - self.lo) * n as f64)
                as usize;
            self.bins[b.min(n - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Render an ASCII bar chart (for figure drivers).
    pub fn ascii(&self, width: usize) -> String {
        let max = self.bins.iter().copied().max().unwrap_or(1).max(1);
        let step = (self.hi - self.lo) / self.bins.len() as f64;
        let mut out = String::new();
        for (i, &c) in self.bins.iter().enumerate() {
            let bar = "#".repeat((c as usize * width / max as usize).max(
                usize::from(c > 0)));
            out.push_str(&format!(
                "{:>9.1}..{:<9.1} |{:<w$}| {}\n",
                self.lo + i as f64 * step,
                self.lo + (i + 1) as f64 * step,
                bar, c, w = width));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_welford() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.n, 8);
        assert!((s.mean - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(42.0);
        assert_eq!(h.bins, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }
}

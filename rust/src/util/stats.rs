//! Small statistics helpers: online summaries, percentiles, histograms.

/// Streaming summary (count/mean/min/max + variance via Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: u64,
    pub mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY,
                  max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile over a sample (linear interpolation, p in [0, 100]).
///
/// This is the *single* exact-percentile implementation in the tree:
/// report aggregation (`coordinator::metrics`), the autoscaler's
/// windowed p99-TTFT signal (via `telemetry::Registry`), and the
/// experiment tables all call here. Bucketed estimates (Prometheus
/// exposition, the telemetry time-series) use [`LogHistogram`] instead
/// — never a third re-derivation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { f64::NAN } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Fixed-bin histogram for trace/figure output.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        Histogram { lo, hi, bins: vec![0; nbins], underflow: 0, overflow: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let b = ((x - self.lo) / (self.hi - self.lo) * n as f64)
                as usize;
            self.bins[b.min(n - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Render an ASCII bar chart (for figure drivers).
    pub fn ascii(&self, width: usize) -> String {
        let max = self.bins.iter().copied().max().unwrap_or(1).max(1);
        let step = (self.hi - self.lo) / self.bins.len() as f64;
        let mut out = String::new();
        for (i, &c) in self.bins.iter().enumerate() {
            let bar = "#".repeat((c as usize * width / max as usize).max(
                usize::from(c > 0)));
            out.push_str(&format!(
                "{:>9.1}..{:<9.1} |{:<w$}| {}\n",
                self.lo + i as f64 * step,
                self.lo + (i + 1) as f64 * step,
                bar, c, w = width));
        }
        out
    }
}

/// Log-bucketed histogram: bucket `i` covers
/// `[lo · growth^i, lo · growth^(i+1))`, values below `lo` land in
/// `underflow`, values past the last edge in `overflow` (the Prometheus
/// `+Inf` bucket). This is the shared bounded-memory distribution type
/// behind the telemetry registry's latency/TTFT series and the
/// Prometheus exposition; quantiles from it are bucket-edge estimates —
/// exact percentiles stay with [`percentile`].
#[derive(Clone, Debug)]
pub struct LogHistogram {
    lo: f64,
    growth: f64,
    counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
    pub count: u64,
    pub sum: f64,
    max: f64,
}

impl LogHistogram {
    pub fn new(lo: f64, growth: f64, nbuckets: usize) -> LogHistogram {
        assert!(lo > 0.0 && growth > 1.0 && nbuckets > 0);
        LogHistogram { lo, growth, counts: vec![0; nbuckets],
                       underflow: 0, overflow: 0, count: 0, sum: 0.0,
                       max: f64::NEG_INFINITY }
    }

    /// Seconds-scaled default: 1 ms to ~17 minutes in quarter-octave
    /// buckets — wide enough for TTFTs and end-to-end latencies alike.
    pub fn seconds() -> LogHistogram {
        LogHistogram::new(1e-3, 2.0_f64.powf(0.25), 80)
    }

    pub fn observe(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        self.count += 1;
        self.sum += x;
        self.max = self.max.max(x);
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        // Walk the edges by repeated multiplication: deterministic and
        // boundary-exact against the same edges `edges()` reports
        // (a log/floor index can mis-bin right on an edge).
        let mut edge = self.lo * self.growth;
        for c in self.counts.iter_mut() {
            if x < edge {
                *c += 1;
                return;
            }
            edge *= self.growth;
        }
        self.overflow += 1;
    }

    /// Upper bucket edges, in order (the Prometheus `le` label values;
    /// `overflow` is the implicit `+Inf` bucket after the last).
    pub fn edges(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.counts.len());
        let mut edge = self.lo;
        for _ in &self.counts {
            edge *= self.growth;
            out.push(edge);
        }
        out
    }

    /// Per-bucket counts (same order as [`LogHistogram::edges`]).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Bucket-edge quantile estimate (p in [0, 100]): the upper edge of
    /// the bucket holding the rank — conservative, like reading a
    /// Prometheus histogram. Underflow reports `lo`, overflow the
    /// observed max. NaN on an empty histogram.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0)
            .min(self.count as f64) as u64;
        let mut seen = self.underflow;
        if seen >= target {
            return self.lo;
        }
        let mut edge = self.lo;
        for &c in &self.counts {
            edge *= self.growth;
            seen += c;
            if seen >= target {
                return edge;
            }
        }
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_welford() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.n, 8);
        assert!((s.mean - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(42.0);
        assert_eq!(h.bins, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }

    /// Pin the log-bucketed quantiles on known inputs: with lo = 1 and
    /// growth = 2 the buckets are [1,2) [2,4) [4,8) [8,16), so every
    /// expected value below is an exact bucket edge.
    #[test]
    fn log_histogram_pins_quantiles_on_known_inputs() {
        let mut h = LogHistogram::new(1.0, 2.0, 4);
        for x in [1.5, 1.5, 3.0, 3.0, 3.0, 3.0, 5.0, 5.0, 5.0, 9.0] {
            h.observe(x);
        }
        assert_eq!(h.count, 10);
        assert_eq!(h.counts(), &[2, 4, 3, 1]);
        assert_eq!(h.edges(), vec![2.0, 4.0, 8.0, 16.0]);
        // ranks: p10 → 1st value (bucket [1,2) → edge 2), p50 → 5th
        // (bucket [2,4) → edge 4), p90 → 9th (bucket [4,8) → edge 8),
        // p99 → 10th (bucket [8,16) → edge 16)
        assert_eq!(h.quantile(10.0), 2.0);
        assert_eq!(h.quantile(50.0), 4.0);
        assert_eq!(h.quantile(90.0), 8.0);
        assert_eq!(h.quantile(99.0), 16.0);
        assert!((h.mean() - 3.9).abs() < 1e-12);
    }

    #[test]
    fn log_histogram_underflow_overflow_and_edge_values() {
        let mut h = LogHistogram::new(1.0, 2.0, 3); // edges 2, 4, 8
        h.observe(0.5); // underflow
        h.observe(2.0); // exactly on an edge → the [2,4) bucket
        h.observe(100.0); // overflow
        h.observe(f64::NAN); // ignored entirely
        assert_eq!(h.count, 3);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.counts(), &[0, 1, 0]);
        // low quantiles report lo, top quantiles the observed max
        assert_eq!(h.quantile(1.0), 1.0);
        assert_eq!(h.quantile(100.0), 100.0);
        let empty = LogHistogram::seconds();
        assert!(empty.quantile(50.0).is_nan());
        assert!(empty.mean().is_nan());
    }
}

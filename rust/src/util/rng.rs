//! Deterministic PRNG (xoshiro256** seeded via SplitMix64).
//!
//! The image ships no `rand` crate, and we need bit-reproducible streams
//! across the workload generator, MCQ task sampling, DQN exploration and
//! the property-test harness anyway — a single in-tree generator keeps
//! every experiment seedable from the CLI.

/// xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small/consecutive seeds give
    /// well-distributed states.
    pub fn new(seed: u64) -> Self {
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Poisson via inversion (small means) — used for arrivals per tick.
    pub fn poisson(&mut self, mean: f64) -> usize {
        if mean <= 0.0 {
            return 0;
        }
        let l = (-mean).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l || k > 10_000 {
                return k;
            }
            k += 1;
        }
    }

    /// Sample an index from an unnormalized non-negative weight slice.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len().max(1));
        }
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose k distinct indices from [0, n) (k <= n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(6);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| r.poisson(3.5) as f64).sum::<f64>() / n as f64;
        assert!((mean - 3.5).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(8);
        let w = [1.0f32, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(9);
        let picks = r.choose_k(10, 5);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 5);
    }
}

//! In-tree substrates: PRNG, JSON, CLI parsing, statistics, bench harness.
//!
//! The offline image vendors only the `xla` crate's dependency closure, so
//! everything that would normally come from `rand` / `serde_json` / `clap`
//! / `criterion` lives here (see DESIGN.md §4).

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;

//! Flight recorder for the RAP control plane: typed event bus,
//! metrics registry, Chrome/Perfetto trace export, and a bounded
//! ring buffer dumped on crash/OOM/terminal rejection.
//!
//! Design rules, in priority order:
//!
//! 1. **Zero cost when disabled.** A disabled [`Bus`] is a `None`
//!    check; event payloads are built inside a closure that is never
//!    called, so hot paths pay one branch.
//! 2. **No observer effect.** Events carry sim time only and touch no
//!    RNG, no clocks, and no scheduling state — seeded
//!    `ServeReport`/`FleetReport` JSON is byte-identical with
//!    telemetry on or off, and trace files are byte-identical across
//!    runs at the same seed (guarded by `tests/telemetry.rs`).
//! 3. **Load-bearing metrics.** The autoscaler's windowed signals read
//!    the [`Registry`] series (`coordinator::fleet::Fleet::signals`)
//!    rather than private mark lists, so what `--metrics` exports is
//!    what the control plane decided on.

pub mod event;
pub mod registry;
pub mod trace;

pub use event::{Event, EventKind, SignalSnapshot};
pub use registry::Registry;

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use crate::api::Tenant;

/// Flight-recorder ring capacity: enough context to read the run-up to
/// a crash without unbounded growth.
pub const FLIGHT_RING_CAP: usize = 256;
/// Dumps kept in full; later triggers only bump `dumps_total`.
pub const MAX_FLIGHT_DUMPS: usize = 8;

/// The last [`FLIGHT_RING_CAP`] events at the moment something went
/// wrong (replica crash, true OOM, terminal rejection).
#[derive(Clone, Debug)]
pub struct FlightDump {
    pub t: f64,
    pub reason: String,
    pub events: Vec<Event>,
}

/// Shared event sink: the append-only audit stream, the bounded ring,
/// and any dumps taken. One recorder serves a whole fleet; engines
/// write through per-replica [`Bus`] handles.
#[derive(Default)]
pub struct Recorder {
    next_seq: u64,
    pub events: Vec<Event>,
    ring: VecDeque<Event>,
    pub dumps: Vec<FlightDump>,
    pub dumps_total: u64,
}

impl Recorder {
    fn push(&mut self, mut ev: Event) {
        ev.seq = self.next_seq;
        self.next_seq += 1;
        if self.ring.len() == FLIGHT_RING_CAP {
            self.ring.pop_front();
        }
        self.ring.push_back(ev.clone());
        self.events.push(ev);
    }

    fn flight_dump(&mut self, t: f64, reason: &str) {
        self.dumps_total += 1;
        if self.dumps.len() < MAX_FLIGHT_DUMPS {
            self.dumps.push(FlightDump {
                t,
                reason: reason.to_string(),
                events: self.ring.iter().cloned().collect(),
            });
        }
    }
}

/// A cheap, cloneable handle an engine (or the fleet) emits through.
/// Disabled by default: [`Bus::emit`] returns before evaluating the
/// event payload, so instrumentation costs one `Option` check on the
/// hot path. Attached handles share one [`Recorder`] and stamp their
/// replica id onto every event.
#[derive(Clone, Default)]
pub struct Bus {
    inner: Option<Rc<RefCell<Recorder>>>,
    replica: Option<usize>,
}

impl Bus {
    pub fn disabled() -> Bus {
        Bus::default()
    }

    pub fn attached(rec: &Rc<RefCell<Recorder>>,
                    replica: Option<usize>) -> Bus {
        Bus { inner: Some(Rc::clone(rec)), replica }
    }

    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Emit one event. `kind` is a closure so payload construction
    /// (string formatting, signal snapshots) is skipped entirely when
    /// the bus is disabled.
    pub fn emit(&self, t: f64, request: Option<u64>,
                tenant: Option<&Tenant>,
                kind: impl FnOnce() -> EventKind) {
        let Some(rec) = &self.inner else { return };
        rec.borrow_mut().push(Event {
            t,
            seq: 0, // assigned by the recorder
            replica: self.replica,
            request,
            tenant: tenant.cloned(),
            kind: kind(),
        });
    }

    /// Snapshot the ring buffer (crash, true OOM, terminal rejection).
    pub fn flight_dump(&self, t: f64, reason: &str) {
        if let Some(rec) = &self.inner {
            rec.borrow_mut().flight_dump(t, reason);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_bus_is_inert_and_skips_payload_construction() {
        let bus = Bus::disabled();
        assert!(!bus.enabled());
        let mut built = false;
        bus.emit(1.0, None, None, || {
            built = true;
            EventKind::Oom
        });
        assert!(!built, "payload closure ran on a disabled bus");
        bus.flight_dump(1.0, "oom"); // no-op, must not panic
    }

    #[test]
    fn recorder_assigns_seq_and_bounds_the_ring() {
        let rec = Rc::new(RefCell::new(Recorder::default()));
        let bus = Bus::attached(&rec, Some(2));
        for i in 0..(FLIGHT_RING_CAP + 10) {
            bus.emit(i as f64, Some(i as u64), None, || EventKind::Admit);
        }
        bus.flight_dump(999.0, "crash: replica 2");
        let r = rec.borrow();
        assert_eq!(r.events.len(), FLIGHT_RING_CAP + 10);
        assert_eq!(r.events[0].seq, 0);
        assert_eq!(r.events.last().unwrap().seq,
                   (FLIGHT_RING_CAP + 9) as u64);
        assert_eq!(r.events[0].replica, Some(2));
        assert_eq!(r.dumps.len(), 1);
        assert_eq!(r.dumps_total, 1);
        let dump = &r.dumps[0];
        assert_eq!(dump.events.len(), FLIGHT_RING_CAP);
        // the ring kept the *latest* events
        assert_eq!(dump.events[0].request, Some(10));
        assert_eq!(dump.reason, "crash: replica 2");
    }

    #[test]
    fn dump_count_is_bounded_but_total_keeps_counting() {
        let rec = Rc::new(RefCell::new(Recorder::default()));
        let bus = Bus::attached(&rec, None);
        bus.emit(0.0, None, None, || EventKind::Oom);
        for i in 0..(MAX_FLIGHT_DUMPS + 3) {
            bus.flight_dump(i as f64, "oom");
        }
        let r = rec.borrow();
        assert_eq!(r.dumps.len(), MAX_FLIGHT_DUMPS);
        assert_eq!(r.dumps_total, (MAX_FLIGHT_DUMPS + 3) as u64);
    }
}

//! Metrics registry: counters, gauges, log-bucketed histograms, and the
//! timestamped mark/value series behind the autoscaler's windowed
//! signals. The registry is *load-bearing*: `Fleet::signals` reads the
//! oom/absorbed/ttft/capacity-loss series from here (replicas no longer
//! keep private mark lists), so the numbers a `--metrics` dump exports
//! are, by construction, the numbers the control plane acted on.
//!
//! Everything here is keyed by sim time; sampling and exposition are
//! pure reads, so enabling output cannot perturb a seeded run.

use std::collections::{BTreeMap, VecDeque};

use crate::util::json::Json;
use crate::util::stats::LogHistogram;

/// Series key for fleet-level (not per-replica) signals.
pub const FLEET: usize = usize::MAX;

/// Series names shared by the signal producers (`Replica::harvest`,
/// fleet crash handling) and readers (`Fleet::signals`, maintenance).
pub mod series {
    /// True OOM events, one mark per event, keyed by replica.
    pub const OOM: &str = "oom";
    /// Mask-absorbed spikes, keyed by replica.
    pub const ABSORBED: &str = "absorbed";
    /// `(finished_at, ttft)` per completed request, keyed by replica.
    pub const TTFT: &str = "ttft";
    /// Replica deaths (crash / expired reclaim), keyed by the
    /// fleet-level sentinel key `FLEET`.
    pub const CAPACITY_LOSS: &str = "capacity-loss";
}

#[derive(Default)]
pub struct Registry {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    histograms: BTreeMap<&'static str, LogHistogram>,
    /// `(series name, replica id)` → time-ordered `(t, value)` points.
    series: BTreeMap<(&'static str, usize), VecDeque<(f64, f64)>>,
    timeline: Vec<Json>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    // ---- counters / gauges (exposition + JSON timeline surface) ----

    pub fn set_counter(&mut self, name: &'static str, v: u64) {
        self.counters.insert(name, v);
    }

    pub fn set_gauge(&mut self, name: &'static str, v: f64) {
        self.gauges.insert(name, v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    // ---- distributions -------------------------------------------

    pub fn observe(&mut self, name: &'static str, x: f64) {
        self.histograms.entry(name).or_insert_with(LogHistogram::seconds)
            .observe(x);
    }

    pub fn histogram(&self, name: &str) -> Option<&LogHistogram> {
        self.histograms.get(name)
    }

    // ---- mark/value series (the signal windows) -------------------
    //
    // The operations below reproduce the exact semantics of the mark
    // lists they replaced, so seeded autoscaler behaviour is unchanged:
    // `count_since` is the non-destructive `ooms_since`/`absorbed_since`
    // read, `trim_count` the destructive `recent_ooms` window used by
    // fleet maintenance, `values_since` the cursor-style TTFT harvest.

    pub fn mark(&mut self, name: &'static str, key: usize, t: f64) {
        self.record(name, key, t, 1.0);
    }

    pub fn record(&mut self, name: &'static str, key: usize, t: f64,
                  v: f64) {
        self.series.entry((name, key)).or_default().push_back((t, v));
    }

    /// Points at `t >= t0`, without discarding older ones.
    pub fn count_since(&self, name: &'static str, key: usize,
                       t0: f64) -> usize {
        match self.series.get(&(name, key)) {
            Some(s) => s.iter().filter(|&&(t, _)| t >= t0).count(),
            None => 0,
        }
    }

    /// Drop points older than `t0`, then count what remains.
    pub fn trim_count(&mut self, name: &'static str, key: usize,
                      t0: f64) -> usize {
        let s = self.series.entry((name, key)).or_default();
        while s.front().is_some_and(|&(t, _)| t < t0) {
            s.pop_front();
        }
        s.len()
    }

    /// Drop points older than `t0` (bounded-memory upkeep).
    pub fn trim(&mut self, name: &'static str, key: usize, t0: f64) {
        self.trim_count(name, key, t0);
    }

    /// Drop points older than `t0`, then append the surviving values to
    /// `out`. Series are time-ordered, so with a monotone `t0` this is
    /// exactly the advancing-cursor read the TTFT window used.
    pub fn values_since(&mut self, name: &'static str, key: usize,
                        t0: f64, out: &mut Vec<f64>) {
        let s = self.series.entry((name, key)).or_default();
        while s.front().is_some_and(|&(t, _)| t < t0) {
            s.pop_front();
        }
        out.extend(s.iter().map(|&(_, v)| v));
    }

    /// Forget a series entirely (e.g. a replica's OOM marks on respawn).
    pub fn clear(&mut self, name: &'static str, key: usize) {
        self.series.remove(&(name, key));
    }

    // ---- time-series output ---------------------------------------

    /// Snapshot every counter and gauge into the JSON timeline.
    pub fn sample(&mut self, t: f64) {
        let mut fields: Vec<(&str, Json)> = vec![("t", Json::Num(t))];
        for (name, v) in &self.counters {
            fields.push((name, Json::Num(*v as f64)));
        }
        for (name, v) in &self.gauges {
            let j = if v.is_finite() { Json::Num(*v) } else { Json::Null };
            fields.push((name, j));
        }
        self.timeline.push(Json::object(fields));
    }

    pub fn samples(&self) -> usize {
        self.timeline.len()
    }

    pub fn timeline_json(&self) -> Json {
        Json::Arr(self.timeline.clone())
    }

    /// Prometheus text exposition of the final counter/gauge/histogram
    /// state. Histogram buckets are cumulative with an explicit `+Inf`,
    /// per the exposition format.
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
        }
        for (name, v) in &self.gauges {
            out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
        }
        for (name, h) in &self.histograms {
            out.push_str(&format!("# TYPE {name} histogram\n"));
            let mut cum = h.underflow;
            for (edge, c) in h.edges().iter().zip(h.counts()) {
                cum += c;
                out.push_str(&format!(
                    "{name}_bucket{{le=\"{edge}\"}} {cum}\n"));
            }
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n",
                                  h.count));
            out.push_str(&format!("{name}_sum {}\n", h.sum));
            out.push_str(&format!("{name}_count {}\n", h.count));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_window_semantics_match_the_old_mark_lists() {
        let mut r = Registry::new();
        r.mark("oom", 1, 2.0);
        r.mark("oom", 1, 9.0);
        r.mark("oom", 1, 9.5);
        // non-destructive read: everything still present afterwards
        assert_eq!(r.count_since("oom", 1, 8.0), 2);
        assert_eq!(r.count_since("oom", 1, 0.0), 3);
        // destructive window: drops t=2.0, keeps counting the rest
        assert_eq!(r.trim_count("oom", 1, 8.0), 2);
        assert_eq!(r.count_since("oom", 1, 0.0), 2);
        // other keys are independent; clearing forgets the series
        assert_eq!(r.count_since("oom", 2, 0.0), 0);
        r.clear("oom", 1);
        assert_eq!(r.count_since("oom", 1, 0.0), 0);
    }

    #[test]
    fn values_since_reads_like_an_advancing_cursor() {
        let mut r = Registry::new();
        r.record("ttft", 0, 1.0, 0.5);
        r.record("ttft", 0, 2.0, 0.7);
        r.record("ttft", 0, 3.0, 0.9);
        let mut out = Vec::new();
        r.values_since("ttft", 0, 2.0, &mut out);
        assert_eq!(out, vec![0.7, 0.9]);
        // t0 only moves forward, so the trim is safe to repeat
        out.clear();
        r.values_since("ttft", 0, 2.5, &mut out);
        assert_eq!(out, vec![0.9]);
    }

    #[test]
    fn prometheus_exposition_has_cumulative_buckets() {
        let mut r = Registry::new();
        r.set_counter("rap_requests_completed_total", 12);
        r.set_gauge("rap_outstanding", 3.0);
        let mut h = LogHistogram::new(1.0, 2.0, 2); // edges 2, 4
        h.observe(1.5);
        h.observe(3.0);
        h.observe(100.0); // +Inf bucket
        r.histograms.insert("rap_ttft_seconds", h);
        let text = r.prometheus();
        assert!(text.contains(
            "# TYPE rap_requests_completed_total counter"));
        assert!(text.contains("rap_requests_completed_total 12"));
        assert!(text.contains("rap_outstanding 3"));
        assert!(text.contains("rap_ttft_seconds_bucket{le=\"2\"} 1"));
        assert!(text.contains("rap_ttft_seconds_bucket{le=\"4\"} 2"));
        assert!(text.contains("rap_ttft_seconds_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("rap_ttft_seconds_count 3"));
    }

    #[test]
    fn timeline_samples_snapshot_counters_and_gauges() {
        let mut r = Registry::new();
        r.set_counter("rap_requests_total", 5);
        r.set_gauge("rap_p99_ttft_seconds", f64::NAN);
        r.sample(10.0);
        r.set_counter("rap_requests_total", 8);
        r.sample(20.0);
        assert_eq!(r.samples(), 2);
        let tl = r.timeline_json();
        let first = &tl.arr().unwrap()[0];
        assert_eq!(first.get("t").unwrap().num().unwrap(), 10.0);
        assert_eq!(first.get("rap_requests_total").unwrap()
                        .usize().unwrap(), 5);
        // NaN gauges sample as null so the dump stays valid JSON
        assert_eq!(first.get("rap_p99_ttft_seconds").unwrap(),
                   &Json::Null);
        let second = &tl.arr().unwrap()[1];
        assert_eq!(second.get("rap_requests_total").unwrap()
                         .usize().unwrap(), 8);
    }
}

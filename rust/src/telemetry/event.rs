//! Typed telemetry events: one variant per control-plane decision or
//! request lifecycle transition, stamped with sim time, replica id,
//! request id, and tenant. The event stream is the ground-truth record
//! the ROADMAP's learned-control-plane item needs — every mask deploy
//! carries its GSI decision inputs and the `MemoryOutlook` lattice at
//! decision time, every autoscale action its triggering signal values.

use crate::api::Tenant;
use crate::util::json::Json;

/// The autoscaler's signal values at the moment it acted — the decision
/// audit attached to every spawn/retire event (a plain copy so the
/// telemetry layer does not depend on coordinator types).
#[derive(Clone, Copy, Debug)]
pub struct SignalSnapshot {
    pub serving: usize,
    pub outstanding: usize,
    pub p99_ttft: f64,
    pub recent_ooms: usize,
    pub recent_absorbed: usize,
    pub capacity_losses: usize,
}

/// What happened. Names (see [`EventKind::name`]) are the stable,
/// greppable vocabulary of the audit stream and the `trace summarize`
/// output.
#[derive(Clone, Debug)]
pub enum EventKind {
    /// A request entered the serving system (engine admission queue, or
    /// the fleet front door before routing).
    Submit,
    /// The router placed the request on a replica.
    Route { dest: usize, policy: String },
    /// Admission popped the request and ran its prefill.
    Admit,
    /// A restored snapshot re-attached its KV in place of a prefill.
    Resume,
    /// Finished decoding; `outcome` is `done` or `deadline-missed`.
    Finish { outcome: &'static str },
    /// Terminal admission rejection.
    Reject { reason: &'static str },
    /// Shed under memory pressure (`mode` = `requeue` or `park`).
    Evict { mode: &'static str },
    /// Displaced by priority-aware admission to fit `for_request`.
    Preempt { for_request: u64 },
    /// Reclaimed through the lifecycle API.
    Cancel,
    /// Terminal `DeadlineMissed` (`site` = where it was caught:
    /// `queue`, `pressure`, or `preempt`).
    DeadlineMiss { site: &'static str },
    /// This sequence's live-KV delta shipped in a checkpoint cycle.
    Checkpoint { bytes: u64 },
    /// With a request id: that sequence's disposition when its replica
    /// died (`checkpointed` / `lost` / `requeued`). Without one: the
    /// replica-level death itself.
    Crash { disposition: &'static str },
    /// A checkpointed sequence landed on a peer and re-entered
    /// admission there.
    Restore { dest: usize },
    /// A sequence moved between replicas (`state` = `active` or
    /// `queued`; `bytes` is the live payload charged to the link).
    Migrate { src: usize, dest: usize, bytes: u64, state: &'static str },
    /// The controller deployed a new mask. Carries the GSI decision
    /// inputs (observed workload + `Sys_avail`) and the
    /// [`MemoryOutlook`](crate::server::outlook::MemoryOutlook) lattice
    /// at decision time; `forced` marks the pressure/admission
    /// min-viable override path.
    MaskDeploy {
        batch: usize,
        seqlen: usize,
        avail: u64,
        min_viable: u64,
        current: u64,
        dense: u64,
        retained: f64,
        forced: bool,
    },
    /// A true OOM: pressure even the joint (mask × KV-policy) floor
    /// could not absorb.
    Oom,
    /// A spike absorbed by the elastic lattice (no work shed).
    AbsorbedSpike,
    /// Per-sequence KV compression engaged under pressure: `seqs`
    /// caches were rewritten to the floor policy, reclaiming `bytes`.
    KvCompress { seqs: u64, bytes: u64 },
    /// The autoscaler added a replica; `trigger` names the signal that
    /// fired (`Autoscaler::explain`).
    AutoscaleSpawn {
        new_replica: usize,
        trigger: &'static str,
        signals: SignalSnapshot,
    },
    /// The autoscaler began draining a replica toward retirement.
    AutoscaleRetire {
        victim: usize,
        trigger: &'static str,
        signals: SignalSnapshot,
    },
    /// A scheduled fault fired (`fault` is the plan entry's
    /// description).
    FaultInjected { fault: String },
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Submit => "submit",
            EventKind::Route { .. } => "route",
            EventKind::Admit => "admit",
            EventKind::Resume => "resume",
            // the terminal outcome IS the event name: life stories read
            // "submit → … → done" without an args indirection
            EventKind::Finish { outcome } => outcome,
            EventKind::Reject { .. } => "reject",
            EventKind::Evict { .. } => "evict",
            EventKind::Preempt { .. } => "preempt",
            EventKind::Cancel => "cancel",
            EventKind::DeadlineMiss { .. } => "deadline-miss",
            EventKind::Checkpoint { .. } => "checkpoint",
            EventKind::Crash { .. } => "crash",
            EventKind::Restore { .. } => "restore",
            EventKind::Migrate { .. } => "migrate",
            EventKind::MaskDeploy { .. } => "mask-deploy",
            EventKind::Oom => "oom",
            EventKind::AbsorbedSpike => "absorbed-spike",
            EventKind::KvCompress { .. } => "kv-compress",
            EventKind::AutoscaleSpawn { .. } => "autoscale-spawn",
            EventKind::AutoscaleRetire { .. } => "autoscale-retire",
            EventKind::FaultInjected { .. } => "fault-injected",
        }
    }

    /// Structured payload for the audit stream (empty for payload-free
    /// kinds).
    fn args(&self) -> Vec<(&'static str, Json)> {
        fn n(x: f64) -> Json {
            if x.is_finite() { Json::Num(x) } else { Json::Null }
        }
        fn u(x: u64) -> Json {
            Json::Num(x as f64)
        }
        fn signals(s: &SignalSnapshot) -> Json {
            Json::object(vec![
                ("serving", u(s.serving as u64)),
                ("outstanding", u(s.outstanding as u64)),
                ("p99_ttft", n(s.p99_ttft)),
                ("recent_ooms", u(s.recent_ooms as u64)),
                ("recent_absorbed", u(s.recent_absorbed as u64)),
                ("capacity_losses", u(s.capacity_losses as u64)),
            ])
        }
        match self {
            EventKind::Route { dest, policy } => vec![
                ("dest", u(*dest as u64)),
                ("policy", Json::Str(policy.clone())),
            ],
            EventKind::Finish { outcome } => {
                vec![("outcome", Json::Str(outcome.to_string()))]
            }
            EventKind::Reject { reason } => {
                vec![("reason", Json::Str(reason.to_string()))]
            }
            EventKind::Evict { mode } => {
                vec![("mode", Json::Str(mode.to_string()))]
            }
            EventKind::Preempt { for_request } => {
                vec![("for_request", u(*for_request))]
            }
            EventKind::DeadlineMiss { site } => {
                vec![("site", Json::Str(site.to_string()))]
            }
            EventKind::Checkpoint { bytes } => vec![("bytes", u(*bytes))],
            EventKind::KvCompress { seqs, bytes } => vec![
                ("seqs", u(*seqs)),
                ("bytes", u(*bytes)),
            ],
            EventKind::Crash { disposition } => {
                vec![("disposition", Json::Str(disposition.to_string()))]
            }
            EventKind::Restore { dest } => {
                vec![("dest", u(*dest as u64))]
            }
            EventKind::Migrate { src, dest, bytes, state } => vec![
                ("src", u(*src as u64)),
                ("dest", u(*dest as u64)),
                ("bytes", u(*bytes)),
                ("state", Json::Str(state.to_string())),
            ],
            EventKind::MaskDeploy { batch, seqlen, avail, min_viable,
                                    current, dense, retained, forced } => {
                vec![
                    ("batch", u(*batch as u64)),
                    ("seqlen", u(*seqlen as u64)),
                    ("avail_bytes", u(*avail)),
                    ("min_viable_bytes", u(*min_viable)),
                    ("current_bytes", u(*current)),
                    ("dense_bytes", u(*dense)),
                    ("retained_fraction", n(*retained)),
                    ("forced", Json::Bool(*forced)),
                ]
            }
            EventKind::AutoscaleSpawn { new_replica, trigger,
                                        signals: s } => vec![
                ("new_replica", u(*new_replica as u64)),
                ("trigger", Json::Str(trigger.to_string())),
                ("signals", signals(s)),
            ],
            EventKind::AutoscaleRetire { victim, trigger, signals: s } => {
                vec![
                    ("victim", u(*victim as u64)),
                    ("trigger", Json::Str(trigger.to_string())),
                    ("signals", signals(s)),
                ]
            }
            EventKind::FaultInjected { fault } => {
                vec![("fault", Json::Str(fault.clone()))]
            }
            _ => Vec::new(),
        }
    }
}

/// One stamped telemetry event. `t` is *sim* time — wall-clock values
/// never enter the event stream (the PR-4 determinism contract: trace
/// files are byte-identical per seed).
#[derive(Clone, Debug)]
pub struct Event {
    pub t: f64,
    /// Global emission order (ties on `t` across replicas are broken by
    /// the order the control plane actually acted in).
    pub seq: u64,
    pub replica: Option<usize>,
    pub request: Option<u64>,
    pub tenant: Option<Tenant>,
    pub kind: EventKind,
}

impl Event {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("t", Json::Num(self.t)),
            ("seq", Json::Num(self.seq as f64)),
            ("event", Json::Str(self.kind.name().to_string())),
        ];
        if let Some(r) = self.replica {
            fields.push(("replica", Json::Num(r as f64)));
        }
        if let Some(id) = self.request {
            fields.push(("request", Json::Num(id as f64)));
        }
        if let Some(tn) = &self.tenant {
            fields.push(("tenant", Json::Str(tn.to_string())));
        }
        let args = self.kind.args();
        if !args.is_empty() {
            fields.push(("args", Json::object(args)));
        }
        Json::object(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_json_carries_stamp_and_args() {
        let ev = Event {
            t: 14.25,
            seq: 7,
            replica: Some(1),
            request: Some(42),
            tenant: Some(crate::api::tenant("burst")),
            kind: EventKind::Migrate { src: 1, dest: 2, bytes: 4096,
                                       state: "active" },
        };
        let j = ev.to_json();
        assert_eq!(j.get("event").unwrap().str().unwrap(), "migrate");
        assert_eq!(j.get("request").unwrap().usize().unwrap(), 42);
        assert_eq!(j.get("replica").unwrap().usize().unwrap(), 1);
        assert_eq!(j.get("tenant").unwrap().str().unwrap(), "burst");
        let args = j.get("args").unwrap();
        assert_eq!(args.get("dest").unwrap().usize().unwrap(), 2);
        assert_eq!(args.get("state").unwrap().str().unwrap(), "active");
    }

    #[test]
    fn finish_events_are_named_by_outcome() {
        let done = EventKind::Finish { outcome: "done" };
        assert_eq!(done.name(), "done");
        let missed = EventKind::Finish { outcome: "deadline-missed" };
        assert_eq!(missed.name(), "deadline-missed");
        // NaN signal values serialize as null, not as invalid JSON
        let spawn = EventKind::AutoscaleSpawn {
            new_replica: 3,
            trigger: "capacity-loss",
            signals: SignalSnapshot { serving: 2, outstanding: 9,
                                      p99_ttft: f64::NAN, recent_ooms: 0,
                                      recent_absorbed: 0,
                                      capacity_losses: 1 },
        };
        let args = Json::object(spawn.args());
        assert_eq!(args.get("trigger").unwrap().str().unwrap(),
                   "capacity-loss");
        assert_eq!(args.get("signals").unwrap().get("p99_ttft").unwrap(),
                   &Json::Null);
    }
}

//! Chrome/Perfetto trace-event export, trace validation, and the
//! `rap trace summarize` life-story reconstruction.
//!
//! The export is the object form of the trace-event format: a
//! `traceEvents` array (loadable by Perfetto / `chrome://tracing`,
//! which ignore unknown sibling keys) plus our own `events` decision
//! audit, `metadata`, and `flightRecorder` dumps. Request lifecycles
//! become span trees on pid 1 (one thread per request id, phases
//! `queued` / `running` / `recovering`); control-plane decisions become
//! instant events on pid 2 (one thread per replica, plus a fleet
//! thread). All timestamps are sim time in microseconds — wall-clock
//! values never appear, so a seeded run exports byte-identical bytes.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::event::{Event, EventKind};
use super::FlightDump;

const PID_REQUESTS: u64 = 1;
const PID_CONTROL: u64 = 2;

fn field_str(ph: &str, name: &str, pid: u64, tid: u64,
             ts: f64) -> Vec<(&'static str, Json)> {
    vec![
        ("ph", Json::Str(ph.to_string())),
        ("name", Json::Str(name.to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts * 1e6)),
    ]
}

fn span_entry(ph: &str, phase: &str, tid: u64, ts: f64) -> Json {
    let mut f = field_str(ph, phase, PID_REQUESTS, tid, ts);
    f.push(("cat", Json::Str("request".to_string())));
    Json::object(f)
}

fn instant_entry(ev: &Event, pid: u64, tid: u64, ts: f64) -> Json {
    let mut f = field_str("i", ev.kind.name(), pid, tid, ts);
    f.push(("s", Json::Str("t".to_string())));
    f.push(("cat", Json::Str("decision".to_string())));
    f.push(("args", ev.to_json()));
    Json::object(f)
}

fn meta_entry(kind: &str, pid: u64, tid: Option<u64>,
              name: &str) -> Json {
    let mut f = vec![
        ("ph", Json::Str("M".to_string())),
        ("name", Json::Str(kind.to_string())),
        ("pid", Json::Num(pid as f64)),
        ("ts", Json::Num(0.0)),
        ("args", Json::object(vec![("name",
                                    Json::Str(name.to_string()))])),
    ];
    if let Some(tid) = tid {
        f.push(("tid", Json::Num(tid as f64)));
    }
    Json::object(f)
}

/// One request's open phase. `last_t` clamps span timestamps to be
/// monotone per thread: engine steps may overshoot the fleet clock, so
/// a crash stamped at the fleet tick can precede the victim's last
/// engine-side event — the audit keeps raw times, the span tree clamps.
#[derive(Default)]
struct Track {
    phase: Option<(&'static str, f64)>,
    last_t: f64,
    seen: bool,
}

/// Build the full trace document from a recorder's event stream.
/// `end_t` closes any still-open spans (requests in flight at shutdown).
pub fn chrome_trace(events: &[Event], dumps: &[FlightDump], end_t: f64,
                    metadata: Vec<(&'static str, Json)>) -> Json {
    let mut entries: Vec<(f64, Json)> = Vec::new();
    let mut tracks: BTreeMap<u64, Track> = BTreeMap::new();
    let mut req_tenant: BTreeMap<u64, String> = BTreeMap::new();
    let mut control_tids: BTreeMap<u64, String> = BTreeMap::new();

    for ev in events {
        let Some(id) = ev.request else {
            // control-plane decision: instant on pid 2
            let (tid, label) = match ev.replica {
                Some(r) => (r as u64 + 1, format!("replica {r}")),
                None => (0, "fleet".to_string()),
            };
            control_tids.entry(tid).or_insert(label);
            entries.push((ev.t, instant_entry(ev, PID_CONTROL, tid,
                                              ev.t)));
            continue;
        };
        if let Some(tn) = &ev.tenant {
            req_tenant.entry(id).or_insert_with(|| tn.to_string());
        }
        let track = tracks.entry(id).or_default();
        track.seen = true;
        let t = ev.t.max(track.last_t);
        track.last_t = t;
        // close the open phase, then decide what (if anything) opens
        let next: Option<&'static str> = match &ev.kind {
            EventKind::Submit | EventKind::Route { .. } => {
                match track.phase {
                    Some(_) => continue, // already tracked; audit-only
                    None => Some("queued"),
                }
            }
            EventKind::Admit | EventKind::Resume => Some("running"),
            EventKind::Evict { .. } | EventKind::Preempt { .. } => {
                Some("queued")
            }
            EventKind::Crash { .. } => Some("recovering"),
            EventKind::Restore { .. } => Some("queued"),
            EventKind::Migrate { state, .. } => {
                if *state == "active" { Some("running") }
                else { Some("queued") }
            }
            EventKind::Finish { .. } | EventKind::Reject { .. }
            | EventKind::Cancel | EventKind::DeadlineMiss { .. } => None,
            // per-request instant, no phase change
            _ => {
                entries.push((t, instant_entry(ev, PID_REQUESTS, id,
                                               t)));
                continue;
            }
        };
        if let Some((phase, t0)) = track.phase.take() {
            entries.push((t0, span_entry("B", phase, id, t0)));
            entries.push((t, span_entry("E", phase, id, t)));
        } else if next.is_none() {
            // terminal with nothing open (e.g. backlog cancel): emit a
            // zero-length queued span so the request still has a track
            entries.push((t, span_entry("B", "queued", id, t)));
            entries.push((t, span_entry("E", "queued", id, t)));
        }
        if matches!(ev.kind, EventKind::Crash { .. }
                             | EventKind::Restore { .. }) {
            entries.push((t, instant_entry(ev, PID_REQUESTS, id, t)));
        }
        if let Some(phase) = next {
            track.phase = Some((phase, t));
        }
    }
    // close spans still open at shutdown
    for (id, track) in &mut tracks {
        if let Some((phase, t0)) = track.phase.take() {
            let t1 = end_t.max(track.last_t);
            entries.push((t0, span_entry("B", phase, *id, t0)));
            entries.push((t1, span_entry("E", phase, *id, t1)));
        }
    }
    // stable sort by timestamp: per-tid emission order is already
    // correct (last_t clamping), ties keep control-plane causal order
    entries.sort_by(|a, b| a.0.total_cmp(&b.0));

    let mut trace_events =
        vec![meta_entry("process_name", PID_REQUESTS, None, "requests"),
             meta_entry("process_name", PID_CONTROL, None,
                        "control-plane")];
    for (tid, label) in &control_tids {
        trace_events.push(meta_entry("thread_name", PID_CONTROL,
                                     Some(*tid), label));
    }
    for (id, track) in &tracks {
        if track.seen {
            let label = match req_tenant.get(id) {
                Some(tn) => format!("req {id} [{tn}]"),
                None => format!("req {id}"),
            };
            trace_events.push(meta_entry("thread_name", PID_REQUESTS,
                                         Some(*id), &label));
        }
    }
    trace_events.extend(entries.into_iter().map(|(_, e)| e));

    let mut meta = metadata;
    meta.push(("requests", Json::Num(tracks.len() as f64)));
    meta.push(("events", Json::Num(events.len() as f64)));
    meta.push(("end_t", Json::Num(end_t)));

    let dump_json: Vec<Json> = dumps.iter().map(|d| {
        Json::object(vec![
            ("t", Json::Num(d.t)),
            ("reason", Json::Str(d.reason.clone())),
            ("events", Json::Arr(d.events.iter().map(Event::to_json)
                                               .collect())),
        ])
    }).collect();

    Json::object(vec![
        ("traceEvents", Json::Arr(trace_events)),
        ("events", Json::Arr(events.iter().map(Event::to_json)
                                          .collect())),
        ("metadata", Json::object(meta)),
        ("flightRecorder", Json::Arr(dump_json)),
    ])
}

pub struct TraceStats {
    pub trace_events: usize,
    pub spans: usize,
    pub instants: usize,
    pub requests: usize,
    pub audit_events: usize,
}

/// Structural validation: monotonic timestamps, balanced begin/end
/// spans per thread, and no orphan request ids (every request in the
/// audit stream has a span track, and vice versa).
pub fn validate(trace: &Json) -> Result<TraceStats> {
    let te = trace.get("traceEvents")
        .context("trace has no traceEvents array")?.arr()?;
    let audit = trace.get("events")
        .context("trace has no decision-audit events array")?.arr()?;
    let mut prev_ts = f64::NEG_INFINITY;
    let mut depth: BTreeMap<(u64, u64), i64> = BTreeMap::new();
    let mut span_tids: BTreeMap<u64, usize> = BTreeMap::new();
    let (mut spans, mut instants) = (0usize, 0usize);
    for (i, e) in te.iter().enumerate() {
        let ph = e.get("ph")?.str()?;
        if ph == "M" {
            continue;
        }
        let ts = e.get("ts")?.num()?;
        if !ts.is_finite() {
            bail!("entry {i}: non-finite ts");
        }
        if ts < prev_ts {
            bail!("entry {i}: ts {ts} goes backwards (prev {prev_ts})");
        }
        prev_ts = ts;
        let pid = e.get("pid")?.num()? as u64;
        let tid = e.get("tid")?.num()? as u64;
        match ph {
            "B" => {
                spans += 1;
                *depth.entry((pid, tid)).or_insert(0) += 1;
                if pid == PID_REQUESTS {
                    *span_tids.entry(tid).or_insert(0) += 1;
                }
            }
            "E" => {
                let d = depth.entry((pid, tid)).or_insert(0);
                *d -= 1;
                if *d < 0 {
                    bail!("entry {i}: end with no begin on \
                           pid {pid} tid {tid}");
                }
            }
            "i" => instants += 1,
            other => bail!("entry {i}: unknown phase {other:?}"),
        }
    }
    for ((pid, tid), d) in &depth {
        if *d != 0 {
            bail!("unbalanced spans on pid {pid} tid {tid}: depth {d}");
        }
    }
    let mut audit_ids: BTreeMap<u64, usize> = BTreeMap::new();
    for e in audit {
        if let Ok(id) = e.get("request").and_then(|j| j.num()) {
            *audit_ids.entry(id as u64).or_insert(0) += 1;
        }
    }
    for id in audit_ids.keys() {
        if !span_tids.contains_key(id) {
            bail!("request {id} appears in the audit stream but has \
                   no span track");
        }
    }
    for id in span_tids.keys() {
        if !audit_ids.contains_key(id) {
            bail!("span track {id} has no audit events (orphan id)");
        }
    }
    Ok(TraceStats { trace_events: te.len(), spans, instants,
                    requests: span_tids.len(),
                    audit_events: audit.len() })
}

fn render_value(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.dumps(),
    }
}

fn render_event_line(e: &Json) -> Result<String> {
    let t = e.get("t")?.num()?;
    let name = e.get("event")?.str()?;
    let replica = match e.get("replica") {
        Ok(r) => format!("replica {}", r.usize()?),
        Err(_) => "fleet    ".to_string(),
    };
    let mut line = format!("  [{t:>9.3}s] {replica:<10} {name:<16}");
    if let Ok(Json::Obj(args)) = e.get("args") {
        let parts: Vec<String> = args.iter()
            .map(|(k, v)| format!("{k}={}", render_value(v)))
            .collect();
        line.push_str(&parts.join(" "));
    }
    Ok(line.trim_end().to_string())
}

/// Reconstruct one request's life story from the decision audit. With
/// no explicit id, picks the most *eventful* request — the one whose
/// lifecycle passed through the most distinct transition kinds (ties
/// break to the smallest id), which in a chaos run is the
/// crash-disturbed one you want to read about.
pub fn summarize(trace: &Json, want: Option<u64>) -> Result<String> {
    let audit = trace.get("events")
        .context("trace has no decision-audit events array")?.arr()?;
    let mut by_req: BTreeMap<u64, Vec<&Json>> = BTreeMap::new();
    for e in audit {
        if let Ok(id) = e.get("request").and_then(|j| j.num()) {
            by_req.entry(id as u64).or_default().push(e);
        }
    }
    if by_req.is_empty() {
        bail!("trace contains no request events");
    }
    let id = match want {
        Some(id) => {
            if !by_req.contains_key(&id) {
                bail!("request {id} not present in trace \
                       ({} requests recorded)", by_req.len());
            }
            id
        }
        None => *by_req.iter()
            .max_by_key(|(id, evs)| {
                let kinds: std::collections::BTreeSet<&str> = evs.iter()
                    .filter_map(|e| e.get("event").and_then(|j| j.str())
                                     .ok())
                    .collect();
                // more distinct kinds first; ties → smallest id
                (kinds.len(), std::cmp::Reverse(**id))
            })
            .map(|(id, _)| id)
            .unwrap(),
    };
    let evs = &by_req[&id];
    let tenant = evs.iter()
        .find_map(|e| e.get("tenant").and_then(|j| j.str()).ok())
        .unwrap_or("-");
    let last = evs.last().unwrap().get("event")?.str()?;
    let mut out = format!(
        "request {id} (tenant {tenant}): {} events, final state: {last}\n",
        evs.len());
    for e in evs {
        out.push_str(&render_event_line(e)?);
        out.push('\n');
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::tenant;

    fn ev(t: f64, seq: u64, replica: Option<usize>, request: Option<u64>,
          kind: EventKind) -> Event {
        Event { t, seq, replica, request,
                tenant: request.map(|_| tenant("acme")), kind }
    }

    /// A crash-disturbed lifecycle: submit → admit → checkpoint →
    /// crash → restore → resume → done, with a capacity-loss spawn in
    /// the control plane.
    fn storyline() -> Vec<Event> {
        use super::super::event::SignalSnapshot;
        let sig = SignalSnapshot { serving: 2, outstanding: 4,
                                   p99_ttft: 1.5, recent_ooms: 0,
                                   recent_absorbed: 0,
                                   capacity_losses: 1 };
        vec![
            ev(1.0, 0, None, Some(7), EventKind::Submit),
            ev(1.0, 1, None, Some(7),
               EventKind::Route { dest: 1, policy: "least".into() }),
            ev(1.2, 2, Some(1), Some(7), EventKind::Submit),
            ev(1.5, 3, Some(1), Some(7), EventKind::Admit),
            ev(2.0, 4, Some(1), Some(7),
               EventKind::Checkpoint { bytes: 2048 }),
            // engine overshoot: event at 2.6 recorded before the fleet
            // crash stamped at 2.5 — the span builder must clamp
            ev(2.6, 5, Some(1), Some(7),
               EventKind::Checkpoint { bytes: 128 }),
            ev(2.5, 6, Some(1), None,
               EventKind::Crash { disposition: "failed" }),
            ev(2.5, 7, Some(1), Some(7),
               EventKind::Crash { disposition: "checkpointed" }),
            ev(2.5, 8, None, None,
               EventKind::AutoscaleSpawn { new_replica: 3,
                                           trigger: "capacity-loss",
                                           signals: sig }),
            ev(3.0, 9, Some(2), Some(7),
               EventKind::Restore { dest: 2 }),
            ev(3.1, 10, Some(2), Some(7), EventKind::Resume),
            ev(4.0, 11, Some(2), Some(7),
               EventKind::Finish { outcome: "done" }),
        ]
    }

    #[test]
    fn exported_trace_validates() {
        let trace = chrome_trace(&storyline(), &[], 5.0, vec![]);
        let stats = validate(&trace).unwrap();
        assert_eq!(stats.requests, 1);
        assert!(stats.spans >= 4); // queued/running/recovering/…
        assert!(stats.instants >= 4); // ckpt ×2, crash, restore, spawn
        assert_eq!(stats.audit_events, 12);
    }

    #[test]
    fn validate_rejects_unbalanced_spans() {
        let trace = chrome_trace(&storyline(), &[], 5.0, vec![]);
        // drop the last E entry → unbalanced
        let te = trace.get("traceEvents").unwrap().arr().unwrap();
        let last_e = te.iter().rposition(|e| {
            e.get("ph").unwrap().str().unwrap() == "E"
        }).unwrap();
        let broken: Vec<Json> = te.iter().enumerate()
            .filter(|(i, _)| *i != last_e)
            .map(|(_, e)| e.clone()).collect();
        let bad = Json::object(vec![
            ("traceEvents", Json::Arr(broken)),
            ("events", trace.get("events").unwrap().clone()),
        ]);
        assert!(validate(&bad).is_err());
    }

    #[test]
    fn summarize_reconstructs_the_crash_disturbed_lifecycle() {
        let trace = chrome_trace(&storyline(), &[], 5.0, vec![]);
        let story = summarize(&trace, None).unwrap();
        assert!(story.starts_with("request 7 (tenant acme)"));
        for step in ["submit", "admit", "checkpoint", "crash",
                     "restore", "resume", "done"] {
            assert!(story.contains(step), "missing {step} in:\n{story}");
        }
        let order: Vec<usize> =
            ["admit", "checkpoint", "crash", "restore", "resume",
             "done"].iter().map(|s| story.find(s).unwrap()).collect();
        assert!(order.windows(2).all(|w| w[0] < w[1]),
                "life story out of order:\n{story}");
        assert!(summarize(&trace, Some(99)).is_err());
    }

    #[test]
    fn span_timestamps_clamp_engine_overshoot() {
        // raw event times go 2.6 → 2.5 across the crash; the span tree
        // must still be monotone (validate checks global ts order)
        let trace = chrome_trace(&storyline(), &[], 5.0, vec![]);
        validate(&trace).unwrap();
        let te = trace.get("traceEvents").unwrap().arr().unwrap();
        let crash_instant = te.iter().find(|e| {
            e.get("ph").unwrap().str().unwrap() == "i"
                && e.get("name").unwrap().str().unwrap() == "crash"
                && e.get("pid").unwrap().num().unwrap() == 1.0
        }).unwrap();
        assert_eq!(crash_instant.get("ts").unwrap().num().unwrap(),
                   2.6 * 1e6);
    }
}

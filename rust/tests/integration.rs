//! Cross-module integration: the full serving engine (batcher + KV
//! manager + memory monitor + controller + PJRT runtime) on the rap-tiny
//! artifacts, plus controller/GSI integration on the real model.

use rap::corpus::Corpus;
use rap::mask::PruneMask;
use rap::memory::MemoryModel;
use rap::runtime::Runtime;
use rap::server::controller::{Controller, Policy};
use rap::server::engine::{Engine, EngineConfig};
use rap::server::memmon::MemoryMonitor;
use rap::util::rng::Rng;
use rap::workload::Request;

fn artifacts() -> std::path::PathBuf {
    rap::artifacts_dir()
}

fn have_artifacts() -> bool {
    artifacts().join("rap-tiny/manifest.json").exists()
}

/// Deterministic toy trace sized for rap-tiny (max_seq 64: prompts fit
/// the t16/t32 prefill buckets, prompt+gen < 64).
fn tiny_trace(n: usize) -> Vec<Request> {
    let mut rng = Rng::new(99);
    (0..n as u64)
        .map(|id| Request {
            id,
            arrival: id as f64 * 0.2,
            prompt_len: rng.range(4, 30),
            gen_len: rng.range(2, 10),
        })
        .collect()
}

fn tiny_calib(rt: &Runtime) -> Vec<i32> {
    let mut rng = Rng::new(7);
    (0..4 * 64).map(|_| rng.below(rt.meta().vocab) as i32).collect()
}

#[test]
fn engine_serves_a_trace_to_completion() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let rt = Runtime::load(&artifacts(), "rap-tiny").unwrap();
    let meta = rt.meta().clone();
    let mem = MemoryModel::new(&meta);
    let calib = tiny_calib(&rt);
    // generous fixed capacity: no pressure, everything must complete
    let monitor = MemoryMonitor::constant(
        mem.dense_peak_bytes(rap::memory::Workload::new(8, meta.max_seq))
            * 4);
    let controller = Controller::new(
        Policy::Static(PruneMask::full(&meta)), mem, calib, 64)
        .with_calib_bucket(4, 64);
    let mut engine =
        Engine::new(rt, monitor, controller, EngineConfig::default());
    let trace = tiny_trace(10);
    let report = engine.run_trace(trace).unwrap();
    assert_eq!(report.completed, 10, "all requests must finish");
    assert_eq!(report.oom_events, 0);
    assert_eq!(report.rejected, 0);
    assert_eq!(report.prefills, 10);
    assert!(report.tokens_generated >= 10 * 3);
    // every completion has coherent timestamps
    for r in &engine.metrics.completed {
        assert!(r.first_token_at >= r.arrival);
        assert!(r.finished_at >= r.first_token_at);
    }
    // engine must batch: fewer decode steps than tokens generated
    assert!(report.decode_steps < report.tokens_generated);
}

#[test]
fn engine_under_pressure_gsi_policy_switches_masks() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::load(&artifacts(), "rap-tiny").unwrap();
    let meta = rt.meta().clone();
    let mem = MemoryModel::new(&meta);
    let calib = tiny_calib(&rt);
    let param_bytes = mem.param_bytes(&PruneMask::full(&meta));
    // capacity BELOW the dense parameters: the controller must prune
    // blocks before it can serve anything at all
    let monitor = MemoryMonitor::constant(param_bytes * 95 / 100);
    let controller =
        Controller::new(Policy::GsiGreedy, mem.clone(), calib, 64)
            .with_calib_bucket(4, 64);
    let mut engine = Engine::new(rt, monitor, controller,
                                 EngineConfig { controller_period: 0.1,
                                                ..Default::default() });
    let report = engine.run_trace(tiny_trace(6)).unwrap();
    assert!(report.mask_switches >= 1,
            "controller never adapted: {report:?}");
    assert!(report.completed >= 4,
            "adaptive policy should still serve: {report:?}");
    // final mask actually dropped something
    assert!(!engine.mask.dropped_blocks().is_empty());
}

#[test]
fn controller_caches_decisions() {
    if !have_artifacts() {
        return;
    }
    let mut rt = Runtime::load(&artifacts(), "rap-tiny").unwrap();
    let meta = rt.meta().clone();
    let mem = MemoryModel::new(&meta);
    let calib = tiny_calib(&rt);
    let mut c = Controller::new(Policy::GsiGreedy, mem.clone(), calib, 64)
        .with_calib_bucket(4, 64);
    let w = rap::memory::Workload::new(4, 32);
    let avail = mem.dense_peak_bytes(w) * 7 / 10;
    let m1 = c.decide(&mut rt, w, avail).unwrap();
    let m2 = c.decide(&mut rt, w, avail).unwrap();
    assert_eq!(m1, m2);
    assert_eq!(c.decisions, 2);
    assert_eq!(c.cache_hits, 1);
    // masks actually meet the budget
    assert!(mem.peak_bytes(&m1, w) <= avail);
}

#[test]
fn full_eval_harness_runs_on_tiny() {
    if !have_artifacts() {
        return;
    }
    // tiny's vocab differs from the shared corpus, so build a synthetic
    // corpus matching its vocab for the harness
    use rap::corpus::MarkovChain;
    let mut rt = Runtime::load(&artifacts(), "rap-tiny").unwrap();
    let meta = rt.meta().clone();
    let v = meta.vocab;
    let mut rng = Rng::new(5);
    let mut trans = vec![0.0f32; v * v];
    for t in 0..v {
        // random sparse rows
        for _ in 0..6 {
            trans[t * v + rng.below(v)] += 1.0;
        }
        let s: f32 = trans[t * v..(t + 1) * v].iter().sum();
        for x in &mut trans[t * v..(t + 1) * v] {
            *x /= s;
        }
    }
    let chain = MarkovChain::new(v, trans.clone(), 0.2, 4).unwrap();
    let uni = MarkovChain::new(v, vec![1.0 / v as f32; v * v], 0.2, 4)
        .unwrap();
    let stream = chain.sample(40_000, &mut rng);
    let corpus = Corpus { chain, chain_ptb: uni, train: stream.clone(),
                          wiki: stream.clone(), ptb: stream.clone(),
                          alpaca: stream };
    let mask = PruneMask::full(&meta);
    let row = rap::evalharness::full_eval(&mut rt, &corpus, &mask,
                                          "dense", 1, 4, 3).unwrap();
    assert!(row.wikitext2_ppl.is_finite() && row.wikitext2_ppl > 1.0);
    assert_eq!(row.task_acc.len(), 7);
    for (name, acc) in &row.task_acc {
        assert!((0.0..=100.0).contains(acc), "{name}: {acc}");
    }
}

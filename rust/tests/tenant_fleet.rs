//! Multi-tenant ingress harness (ISSUE 5): the tenant-storm acceptance
//! scenario — tenant-fair routing must hold the latency-sensitive
//! tenant's p99 TTFT and deadline hit-rate strictly better than FCFS on
//! the same seeded trace while the noisy tenant stays within its KV
//! quota — plus the lifecycle proptests: cancellation conserves
//! sequences (no leaked KV bytes), and the tenant-fair quota is a hard
//! cap (no tenant's committed KV bytes ever exceed it).

use rap::api::{Outcome, RequestStatus, SubmitRequest, TenantQuotas};
use rap::coordinator::fleet::{tenant_storm_fcfs_trace,
                              tenant_storm_fleet, tenant_storm_trace,
                              uniform_sim_fleet, Fleet, FleetConfig};
use rap::coordinator::metrics::{FleetReport, FleetTenantReport};
use rap::coordinator::replica::ReplicaSpec;
use rap::coordinator::router::RouterPolicy;
use rap::mask::PruneMask;
use rap::memory::MemoryModel;
use rap::model_meta::ModelMeta;
use rap::runtime::Runtime;
use rap::server::controller::{Controller, Policy};
use rap::server::engine::{Engine, EngineConfig};
use rap::server::memmon::MemoryMonitor;
use rap::util::rng::Rng;

fn tenant<'a>(r: &'a FleetReport, name: &str) -> &'a FleetTenantReport {
    r.tenants
        .iter()
        .find(|t| t.tenant == name)
        .unwrap_or_else(|| panic!("tenant '{name}' missing: {r:?}"))
}

/// The ISSUE-5 acceptance inequality on the CI smoke seed: on the same
/// seeded two-tenant storm, the tenant-fair ingress must strictly beat
/// FCFS (round-robin dispatch-on-arrival) for the latency-sensitive
/// tenant on BOTH p99 TTFT and deadline hit-rate, while the noisy
/// tenant's committed KV bytes never exceed its quota. Reproducible via
/// `rap experiment fleet --tenants --seed 42`.
#[test]
fn tenant_fair_beats_fcfs_on_the_tenant_storm() {
    let seed = 42;
    let reqs = tenant_storm_trace(seed);
    let n = reqs.len() as u64;
    // the baseline is the legacy front door: round-robin dispatch over
    // FCFS queues (priorities flattened), deadlines measured only
    let mut fcfs = tenant_storm_fleet(seed, RouterPolicy::RoundRobin);
    let fr = fcfs.run_requests(tenant_storm_fcfs_trace(seed)).unwrap();
    let mut fair = tenant_storm_fleet(seed, RouterPolicy::TenantFair);
    let tr = fair.run_requests(reqs).unwrap();

    let f_lat = tenant(&fr, "latency");
    let t_lat = tenant(&tr, "latency");
    let t_noisy = tenant(&tr, "noisy");

    // the storm really hurts the baseline: some deadlines are missed
    assert!(f_lat.counts.deadline_missed >= 1,
            "FCFS missed no deadlines — the storm is toothless: {fr:?}");
    // the acceptance inequality, strict on both axes
    assert!(t_lat.p99_ttft < f_lat.p99_ttft,
            "tenant-fair p99 TTFT not strictly better: {:.3} vs {:.3}",
            t_lat.p99_ttft, f_lat.p99_ttft);
    assert!(t_lat.deadline_hit_rate() > f_lat.deadline_hit_rate(),
            "tenant-fair hit-rate not strictly better: {:.3} vs {:.3}",
            t_lat.deadline_hit_rate(), f_lat.deadline_hit_rate());
    // the noisy tenant stays within its KV quota (hard cap)
    let quota = t_noisy.quota_bytes.expect("noisy quota configured");
    assert!(t_noisy.quota_peak_bytes <= quota,
            "noisy tenant breached its quota: {} > {}",
            t_noisy.quota_peak_bytes, quota);
    // fairness is not starvation: the noisy flood still drains
    assert!(t_noisy.counts.finished >= 1,
            "the noisy tenant was starved outright: {tr:?}");
    // conservation on both runs: every arrival reached exactly one
    // terminal state in the per-tenant ledger — finished in-SLO,
    // deadline-missed (late finish, queue expiry, or expired shed),
    // cancelled, or rejected
    for r in [&fr, &tr] {
        let lat = tenant(r, "latency");
        let noisy = tenant(r, "noisy");
        let accounted = |t: &FleetTenantReport| {
            t.counts.finished + t.counts.deadline_missed
                + t.counts.cancelled + t.counts.rejected
        };
        assert_eq!(accounted(lat) + accounted(noisy), n,
                   "arrivals unaccounted for: {r:?}");
    }
}

/// Same seed twice → byte-identical report JSON (the determinism
/// contract extends to the multi-tenant surface).
#[test]
fn tenant_storm_is_deterministic_per_seed() {
    let run = |seed: u64| {
        let mut fleet = tenant_storm_fleet(seed, RouterPolicy::TenantFair);
        let report =
            fleet.run_requests(tenant_storm_trace(seed)).unwrap();
        report.to_json().pretty()
    };
    let a = run(11);
    let b = run(11);
    assert_eq!(a, b, "same seed must reproduce the report byte for byte");
    let c = run(12);
    assert_ne!(a, c, "different seeds should differ");
}

fn sim_engine() -> Engine {
    let meta = ModelMeta::synthetic("tf", 4, 128, 8, 4, 512, 512, 256);
    let rt = Runtime::synthetic(meta.clone(), 1);
    let mem = MemoryModel::new(&meta);
    let capacity = mem.param_bytes(&PruneMask::full(&meta)) * 4;
    let monitor = MemoryMonitor::constant(capacity);
    let controller = Controller::new(
        Policy::Static(PruneMask::full(&meta)), mem, vec![0; 128], 128)
        .with_calib_bucket(1, 128);
    Engine::new(rt, monitor, controller, EngineConfig::default())
}

/// Lifecycle proptest (ISSUE 5): random submit/step/cancel interleaves
/// conserve sequences — after the engine drains, every id holds exactly
/// one terminal outcome, cancelled ids freed their KV, and the
/// footprint collapses back to the bare model (no leaked KV bytes).
#[test]
fn prop_cancellation_conserves_sequences() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed ^ 0xCA7CE1);
        let mut e = sim_engine();
        let n = rng.range(4, 16) as u64;
        for id in 0..n {
            e.submit(SubmitRequest::new(rng.range(2, 120),
                                        rng.range(2, 30))
                .with_id(id));
        }
        let mut cancelled = std::collections::HashSet::new();
        let mut t = 0.0;
        for _ in 0..rng.range(1, 30) {
            t += rng.f64() * 0.2;
            e.step_to(t).unwrap();
            let id = rng.below(n as usize) as u64;
            if e.cancel(id).unwrap() {
                cancelled.insert(id);
            }
        }
        e.step_to(t + 300.0).unwrap();
        assert!(e.idle(), "seed {seed}: engine never drained");
        // no KV bytes leak past a cancel (or a completion)
        assert_eq!(e.kv.len(), 0, "seed {seed}: leaked caches");
        assert_eq!(e.bytes_used(), e.mem.param_bytes(&e.mask),
                   "seed {seed}: footprint above the bare model");
        // exactly one terminal outcome per id, consistent with the
        // cancels that reported success
        let mut done = 0usize;
        for id in 0..n {
            match e.metrics.outcome(id) {
                Some(Outcome::Cancelled) => {
                    assert!(cancelled.contains(&id),
                            "seed {seed}: phantom cancel of {id}");
                }
                Some(Outcome::Done) => {
                    assert!(!cancelled.contains(&id),
                            "seed {seed}: {id} both done and cancelled");
                    done += 1;
                }
                other => panic!(
                    "seed {seed}: id {id} ended as {other:?}"),
            }
        }
        assert_eq!(e.metrics.completed.len(), done, "seed {seed}");
        assert_eq!(e.metrics.cancelled as usize, cancelled.len(),
                   "seed {seed}");
    }
}

/// Independently recompute each tenant's committed KV bytes from the
/// engines' real state (queued + active, priced exactly like the
/// dispatcher prices them) — NOT from the fleet's own `quota_peak`
/// counter, so the quota proptest checks the invariant against the
/// engines rather than the dispatcher's arithmetic against itself.
fn committed_by_tenant(fleet: &Fleet)
                       -> std::collections::BTreeMap<String, u64> {
    let mut m = std::collections::BTreeMap::new();
    for r in &fleet.replicas {
        let e = &r.engine;
        for req in e.batcher.waiting.iter() {
            *m.entry(req.tenant.to_string()).or_insert(0u64) +=
                e.admission_cost(req) as u64;
        }
        for s in e.batcher.active.iter() {
            *m.entry(s.req.tenant.to_string()).or_insert(0u64) +=
                e.admission_cost(&s.req) as u64;
        }
    }
    m
}

/// Quota proptest (ISSUE 5): under tenant-fair routing with finite
/// quotas, no tenant's committed KV bytes ever exceed its quota. The
/// fleet is driven manually (`submit` + `step`) and the committed
/// bytes are re-derived from engine state at every step boundary, so
/// the check is independent of the dispatcher's own accounting — and
/// holding tenants at their caps loses no work.
#[test]
fn prop_tenant_fair_never_exceeds_quota() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed ^ 0x7E4A47);
        let spec = ReplicaSpec {
            flops_per_sec: 1.0e8,
            app_rate: 0.0,
            adaptive: false,
            capacity_mult: 2.5,
            ..ReplicaSpec::heterogeneous(0)
        };
        let cfg = FleetConfig {
            oom_threshold: usize::MAX,
            max_sim_secs: 4000.0,
            ..FleetConfig::default()
        };
        let mut fleet = uniform_sim_fleet(2, seed,
                                          RouterPolicy::TenantFair,
                                          cfg, spec);
        // quotas in units of one worst-case request's projected KV
        let unit =
            fleet.replicas[0].engine.kv_bytes_for_len(176) as u64;
        let names = ["a", "b", "c"];
        let mut quotas = TenantQuotas::unlimited();
        let mut quota_of = std::collections::BTreeMap::new();
        for name in names {
            let q = rng.range(2, 8) as u64 * unit;
            quotas = quotas.with_quota(name, q);
            quota_of.insert(name.to_string(), q);
        }
        fleet.router.quotas = quotas;
        let n = rng.range(20, 60) as u64;
        let mut reqs: Vec<SubmitRequest> = (0..n)
            .map(|id| {
                SubmitRequest::new(rng.range(2, 120), rng.range(2, 48))
                    .with_id(id)
                    .with_arrival(rng.f64() * 20.0)
                    .with_tenant(names[rng.below(3)])
            })
            .collect();
        reqs.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        let mut handles = Vec::new();
        let mut peaks: std::collections::BTreeMap<String, u64> =
            std::collections::BTreeMap::new();
        let mut next = 0usize;
        let mut t = 0.0;
        loop {
            t += 0.25;
            fleet.step(t).unwrap();
            while next < reqs.len() && reqs[next].arrival <= t {
                handles.push(fleet.submit(reqs[next].clone()));
                next += 1;
            }
            for (name, bytes) in committed_by_tenant(&fleet) {
                let p = peaks.entry(name).or_insert(0);
                if bytes > *p {
                    *p = bytes;
                }
            }
            // the incremental committed-bytes ledger the dispatcher
            // now routes on must stay byte-equal to the full rescan
            // at every step boundary, on every engine
            for r in &fleet.replicas {
                let mut ledger = std::collections::BTreeMap::new();
                let mut rescan = std::collections::BTreeMap::new();
                r.engine.committed_kv_bytes(&mut ledger);
                r.engine.committed_kv_bytes_rescan(&mut rescan);
                assert_eq!(ledger, rescan,
                           "seed {seed}: replica {} ledger drifted \
                            from the rescan at t={t}", r.id);
            }
            if next >= reqs.len()
                && handles.iter().all(|h| {
                    matches!(fleet.poll(*h),
                             Some(RequestStatus::Finished(_)))
                })
            {
                break;
            }
            assert!(t < 3000.0, "seed {seed}: fleet never drained");
        }
        // the engines' real committed bytes never breached a quota
        for (name, peak) in &peaks {
            let quota = quota_of[name];
            assert!(*peak <= quota,
                    "seed {seed}: tenant {name} committed {peak} over \
                     quota {quota}");
        }
        // the caps throttle, they must not lose work
        let report = fleet.report();
        assert_eq!(report.completed as u64 + report.rejected
                       + report.dropped, n,
                   "seed {seed}: arrivals unaccounted: {report:?}");
    }
}

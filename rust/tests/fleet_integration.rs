//! Fleet-level integration: multi-replica serving over the sim runtime
//! backend. The headline check is the ISSUE-2 acceptance criterion: on
//! the same trace, under interference, the RAP-aware router produces
//! fewer total OOM events than round-robin — because it reads each
//! replica's Sys_avail(t) and current mask instead of dispatching
//! blindly.

use rap::coordinator::fleet::{default_fleet_trace, default_sim_fleet,
                              Fleet, FleetConfig};
use rap::coordinator::replica::Replica;
use rap::coordinator::router::{Router, RouterPolicy};
use rap::mask::PruneMask;
use rap::memory::MemoryModel;
use rap::model_meta::ModelMeta;
use rap::runtime::Runtime;
use rap::server::controller::{Controller, Policy};
use rap::server::engine::{Engine, EngineConfig};
use rap::server::memmon::MemoryMonitor;
use rap::util::json::Json;
use rap::workload::Request;

fn sim_meta() -> ModelMeta {
    ModelMeta::synthetic("itest", 4, 128, 8, 4, 512, 512, 256)
}

/// A two-replica fleet where replica 0 is chronically underwater
/// (explicit interference schedule leaves half the dense parameter
/// footprint available, forever) and replica 1 is roomy and quiet. Both
/// run static dense deployments so the *only* difference between runs is
/// the routing policy.
fn pressured_fleet(policy: RouterPolicy) -> Fleet {
    let meta = sim_meta();
    let mut replicas = Vec::new();
    for id in 0..2usize {
        let rt = Runtime::synthetic(meta.clone(), 77 + id as u64);
        let mem = MemoryModel::new(&meta);
        let params = mem.param_bytes(&PruneMask::full(&meta));
        let monitor = if id == 0 {
            let cap = (params as f64 * 1.2) as usize;
            MemoryMonitor::walls(cap, &[(0.0, 1e12, cap - params / 2)])
        } else {
            MemoryMonitor::constant(params * 6)
        };
        let controller = Controller::new(
            Policy::Static(PruneMask::full(&meta)), mem, vec![0; 128],
            128)
            .with_calib_bucket(1, 128);
        let engine = Engine::new(rt, monitor, controller,
                                 EngineConfig::default());
        replicas.push(Replica::new(id, engine));
    }
    Fleet::new(replicas, Router::new(policy, 2), FleetConfig {
        oom_threshold: usize::MAX, // isolate routing: no drain/respawn
        ..FleetConfig::default()
    })
}

fn fixed_trace() -> Vec<Request> {
    (0..40)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.5,
            prompt_len: 16,
            gen_len: 8,
        })
        .collect()
}

#[test]
fn rap_router_beats_round_robin_on_oom_under_interference() {
    let mut rr = pressured_fleet(RouterPolicy::RoundRobin);
    let rr_report = rr.run_trace(fixed_trace()).unwrap();
    let mut rap = pressured_fleet(RouterPolicy::RapAware);
    let rap_report = rap.run_trace(fixed_trace()).unwrap();

    // round-robin blindly sends half the trace to the underwater
    // replica: every such request trips a memory-pressure event
    assert_eq!(rr_report.routing, vec![20, 20]);
    assert!(rr_report.oom_events >= 10,
            "expected heavy OOM pressure under round-robin, got {}",
            rr_report.oom_events);

    // the RAP-aware router reads Sys_avail(t) + footprint and never
    // places work on the underwater replica
    assert_eq!(rap_report.routing[0], 0,
               "rap-aware routed to the underwater replica");
    assert_eq!(rap_report.oom_events, 0);
    assert!(rap_report.oom_events < rr_report.oom_events,
            "rap {} vs rr {}", rap_report.oom_events,
            rr_report.oom_events);

    // and it completes the whole trace on the healthy replica
    assert_eq!(rap_report.completed, 40);
    assert!(rr_report.completed < 40,
            "round-robin should lose the requests it sent under water");
}

#[test]
fn default_fleet_emits_complete_json_report() {
    let mut fleet = default_sim_fleet(4, 7, RouterPolicy::RapAware);
    let reqs = default_fleet_trace(7, 60.0);
    let n = reqs.len() as u64;
    let report = fleet.run_trace(reqs).unwrap();
    assert_eq!(report.replicas.len(), 4);
    assert_eq!(report.total_requests, n);
    assert!(report.completed > 0);

    // heterogeneity: at least two distinct capacities in the fleet
    let mut caps: Vec<usize> =
        report.replicas.iter().map(|r| r.capacity_bytes).collect();
    caps.sort_unstable();
    caps.dedup();
    assert!(caps.len() >= 2, "fleet is not heterogeneous");

    // the JSON surface carries per-replica + aggregate tails, OOM
    // counts, and the routing histogram — and round-trips the parser
    let json = report.to_json().pretty();
    let parsed = Json::parse(&json).expect("FleetReport JSON must parse");
    assert_eq!(parsed.get("replicas").unwrap().arr().unwrap().len(), 4);
    assert_eq!(
        parsed.get("routing_histogram").unwrap().usize_vec().unwrap()
            .iter().sum::<usize>() as u64
            + parsed.get("dropped").unwrap().usize().unwrap() as u64,
        n);
    for key in ["p50_latency", "p99_latency", "p50_ttft", "p99_ttft",
                "oom_events", "completed", "router"] {
        assert!(parsed.get(key).is_ok(), "missing aggregate key {key}");
    }
    for rep in parsed.get("replicas").unwrap().arr().unwrap() {
        for key in ["p50_latency", "p99_latency", "oom_events",
                    "routed", "state"] {
            assert!(rep.get(key).is_ok(), "missing replica key {key}");
        }
    }
}

#[test]
fn all_router_policies_complete_a_calm_trace() {
    // with generous capacity and no interference, every policy must
    // serve the full trace — policies differ in placement, not safety
    for policy in RouterPolicy::ALL {
        let meta = sim_meta();
        let mem = MemoryModel::new(&meta);
        let params = mem.param_bytes(&PruneMask::full(&meta));
        let mut replicas = Vec::new();
        for id in 0..3usize {
            let rt = Runtime::synthetic(meta.clone(), id as u64);
            let controller = Controller::new(
                Policy::Static(PruneMask::full(&meta)),
                MemoryModel::new(&meta), vec![0; 128], 128)
                .with_calib_bucket(1, 128);
            let engine = Engine::new(
                rt, MemoryMonitor::constant(params * 8), controller,
                EngineConfig::default());
            replicas.push(Replica::new(id, engine));
        }
        let mut fleet = Fleet::new(replicas, Router::new(policy, 3),
                                   FleetConfig::default());
        let report = fleet.run_trace(fixed_trace()).unwrap();
        assert_eq!(report.completed, 40, "{} lost requests",
                   policy.name());
        assert_eq!(report.oom_events, 0, "{}", policy.name());
        assert_eq!(report.dropped, 0, "{}", policy.name());
    }
}

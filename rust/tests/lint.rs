//! `rap lint` harness (ISSUE 10): the static-analysis pass scanned
//! against its committed fixture files — every rule × {fires, clean,
//! allowed-with-justification, allowed-without-justification-is-an-
//! error} — plus the self-scan gate: the crate's own `src/` tree must
//! carry ZERO unjustified findings, so a regression that reintroduces
//! wall-clock reads, hash-order iteration, partial_cmp, hot-path
//! panics, or raw rng fails `cargo test` before it ever reaches CI's
//! dedicated lint job.

use std::path::PathBuf;

use rap::analysis::{default_src_root, scan_path, scan_source, Finding,
                    RULES};

/// (fixture stem, rule name, virtual path the harness scans it under).
const CASES: [(&str, &str, &str); 5] = [
    ("wall_clock", "wall-clock", "server/fixture.rs"),
    ("unordered_iter", "unordered-iter", "coordinator/fixture.rs"),
    ("float_ordering", "float-ordering", "server/fixture.rs"),
    ("hot_path_panic", "hot-path-panic", "server/fixture.rs"),
    ("raw_rng", "raw-rng", "server/fixture.rs"),
];

fn fixture(name: &str) -> String {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("src/analysis/fixtures")
        .join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// Findings of one rule, split (unjustified, justified).
fn split(rule: &str, fs: &[Finding]) -> (Vec<Finding>, Vec<Finding>) {
    fs.iter()
        .filter(|f| f.rule == rule)
        .cloned()
        .partition(|f| f.justification.is_none())
}

#[test]
fn every_rule_fires_on_its_dirty_fixture() {
    for (stem, rule, virt) in CASES {
        let fs = scan_source(virt, &fixture(&format!("{stem}_dirty.rs")));
        let (bad, just) = split(rule, &fs);
        assert_eq!(bad.len(), 2,
                   "{stem}_dirty: want 2 unjustified {rule}, got {bad:?}");
        assert_eq!(just.len(), 0,
                   "{stem}_dirty: want 0 justified {rule}, got {just:?}");
    }
}

#[test]
fn every_rule_stays_quiet_on_its_clean_fixture() {
    for (stem, rule, virt) in CASES {
        let fs = scan_source(virt, &fixture(&format!("{stem}_clean.rs")));
        let (bad, just) = split(rule, &fs);
        assert_eq!(bad.len(), 0,
                   "{stem}_clean: want 0 unjustified {rule}, got {bad:?}");
        assert_eq!(just.len(), 1,
                   "{stem}_clean: want 1 justified {rule}, got {just:?}");
        assert!(just[0].justification.as_deref()
                    .is_some_and(|j| !j.is_empty()),
                "{stem}_clean: justification text must be non-empty");
    }
}

#[test]
fn allow_without_justification_is_still_a_finding() {
    // every dirty fixture's second violation carries a bare
    // `lint:allow(<rule>)` — it must stay unjustified AND say why
    for (stem, rule, virt) in CASES {
        let fs = scan_source(virt, &fixture(&format!("{stem}_dirty.rs")));
        let (bad, _) = split(rule, &fs);
        let flagged: Vec<_> = bad.iter()
            .filter(|f| f.message.contains("lacks a justification"))
            .collect();
        assert_eq!(flagged.len(), 1,
                   "{stem}_dirty: exactly one bare-suppression finding \
                    expected, got {bad:?}");
    }
}

#[test]
fn scoped_rules_stay_quiet_outside_their_scope() {
    // the same dirty sources, re-scanned under a path outside the
    // rule's scope dirs, must produce nothing
    for stem in ["hot_path_panic", "unordered_iter"] {
        let (_, rule, _) =
            CASES.iter().find(|c| c.0 == stem).copied().unwrap();
        let fs = scan_source("agent/fixture.rs",
                             &fixture(&format!("{stem}_dirty.rs")));
        let (bad, just) = split(rule, &fs);
        assert!(bad.is_empty() && just.is_empty(),
                "{stem}_dirty out of scope: want 0 {rule} findings, \
                 got {bad:?} {just:?}");
    }
}

#[test]
fn test_code_is_exempt() {
    let src = "fn live() { x.unwrap(); }\n\
               #[cfg(test)]\n\
               mod tests {\n\
                   fn t() {\n\
                       let t0 = std::time::Instant::now();\n\
                       y.unwrap();\n\
                   }\n\
               }\n";
    let fs = scan_source("server/demo.rs", src);
    assert_eq!(fs.iter().filter(|f| f.rule == "wall-clock").count(), 0,
               "wall-clock inside #[cfg(test)] must not fire");
    let panics: Vec<_> =
        fs.iter().filter(|f| f.rule == "hot-path-panic").collect();
    assert_eq!(panics.len(), 1, "only the live-path unwrap fires");
    assert_eq!(panics[0].line, 1);
}

#[test]
fn rule_catalog_matches_the_fixture_set() {
    assert_eq!(RULES.len(), CASES.len());
    for (_, rule, _) in CASES {
        assert!(RULES.iter().any(|r| r.name == rule),
                "fixture rule {rule} missing from RULES catalog");
    }
}

/// The gate itself: the shipped tree carries zero unjustified
/// findings, and the deliberate exceptions (benchmark wall-clock,
/// audited hot-path expects) are present AND justified — if someone
/// deletes a justification, or adds a violation, this fails locally
/// before CI does.
#[test]
fn self_scan_holds_the_tree_clean() {
    let findings = scan_path(&default_src_root())
        .expect("scanning the crate's own src/ tree");
    let bad: Vec<_> = findings.iter()
        .filter(|f| f.justification.is_none())
        .collect();
    assert!(bad.is_empty(),
            "unjustified lint findings in the shipped tree:\n{}",
            bad.iter()
                .map(|f| format!("  {}:{} [{}] {}", f.file, f.line,
                                 f.rule, f.snippet))
                .collect::<Vec<_>>()
                .join("\n"));
    // the deliberate, audited exceptions exist — both families
    for rule in ["wall-clock", "hot-path-panic"] {
        assert!(findings.iter().any(|f| f.rule == rule
                                    && f.justification.is_some()),
                "expected at least one justified {rule} allow in-tree");
    }
}

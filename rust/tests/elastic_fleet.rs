//! Elastic-fleet scenario harness (ISSUE 3): deterministic seeded
//! traces — ramp-up, burst-storm, drain-down — driving autoscaling and
//! cross-replica migration end to end, with three classes of assertion:
//!
//!   (a) the autoscaler converges without oscillation: it scales up
//!       under a ramp, sheds capacity on the drain, and the total
//!       spawn/retire count stays inside the bound its cooldown
//!       guarantees;
//!   (b) migration strictly reduces OOM evictions vs local requeue on
//!       the same trace (and the acceptance comparison: the elastic
//!       fleet beats the fixed drain/respawn fleet on both evictions
//!       and p99 TTFT on the same seeded burst storm);
//!   (c) the `FleetReport` JSON is byte-identical across two runs with
//!       the same seed (and carries no wall-clock-derived fields);
//!   (d) mask-elastic accounting (ISSUE 4): on a seeded trace whose
//!       interference spike is fully absorbable by mask-shrinking, the
//!       outlook-gated fleet performs zero migrations and spawns where
//!       current-mask accounting performs several, at a better p99
//!       TTFT.
//!
//! The decisive PR-3 comparisons run on slow sim devices with static
//! dense controllers and explicit interference walls, so the outcome is
//! a property of the fleet mechanics, not of controller adaptivity or
//! seeded interference luck; the PR-4 comparison runs *adaptive*
//! controllers on both sides — the accounting, not the controller, is
//! the only difference.

use rap::coordinator::fleet::{absorbable_spike_fleet,
                              absorbable_spike_trace, burst_storm_trace,
                              drain_down_trace, elastic_demo_fleet,
                              elastic_demo_trace, ramp_up_trace,
                              uniform_sim_fleet, AutoscaleConfig, Fleet,
                              FleetConfig};
use rap::coordinator::replica::ReplicaSpec;
use rap::coordinator::router::RouterPolicy;
use rap::workload::Request;

/// A slow, memory-quiet uniform spec: sequences live long enough for
/// queues (and autoscaler signals) to build, and nothing OOMs unless a
/// test says so.
fn slow_quiet_spec() -> ReplicaSpec {
    ReplicaSpec {
        flops_per_sec: 2.0e7,
        app_rate: 0.0,
        adaptive: false,
        capacity_mult: 2.5,
        ..ReplicaSpec::heterogeneous(0)
    }
}

fn autoscale_cfg(min: usize, max: usize) -> FleetConfig {
    FleetConfig {
        autoscale: Some(AutoscaleConfig {
            min_replicas: min,
            max_replicas: max,
            ..AutoscaleConfig::default()
        }),
        max_sim_secs: 4000.0,
        ..FleetConfig::default()
    }
}

/// The cooldown-derived ceiling on scale actions for a run of `secs`.
fn action_bound(cfg: &FleetConfig, secs: f64) -> u64 {
    let cooldown = cfg.autoscale.unwrap().cooldown_secs;
    (secs / cooldown).ceil() as u64 + 1
}

#[test]
fn ramp_up_scales_up_without_oscillation() {
    let cfg = autoscale_cfg(2, 6);
    let mut fleet = uniform_sim_fleet(2, 17, RouterPolicy::LeastOutstanding,
                                      cfg, slow_quiet_spec());
    let reqs = ramp_up_trace(17, 120.0);
    let n = reqs.len();
    let report = fleet.run_trace(reqs).unwrap();
    assert!(report.spawns >= 1,
            "a 12× ramp on slow devices must scale up: {report:?}");
    // convergence: bounded events, not a spawn/retire ping-pong
    let bound = action_bound(&cfg, report.sim_secs);
    assert!(report.spawns + report.retires <= bound,
            "oscillation: {} spawns + {} retires > bound {bound}",
            report.spawns, report.retires);
    assert!(report.replicas.len() <= 6, "scaled past max_replicas");
    // quiet memory: nothing lost, the ramp is a latency problem only
    assert_eq!(report.completed, n);
    assert_eq!(report.oom_events, 0);
    assert_eq!(report.evictions, 0);
}

#[test]
fn drain_down_retires_idle_capacity() {
    let cfg = autoscale_cfg(1, 6);
    let mut fleet = uniform_sim_fleet(4, 23, RouterPolicy::LeastOutstanding,
                                      cfg, slow_quiet_spec());
    let reqs = drain_down_trace(23, 120.0);
    let n = reqs.len();
    let report = fleet.run_trace(reqs).unwrap();
    assert_eq!(report.completed, n);
    let bound = action_bound(&cfg, report.sim_secs);
    assert!(report.spawns + report.retires <= bound,
            "oscillation: {} spawns + {} retires > bound {bound}",
            report.spawns, report.retires);

    // `run_trace` returns the moment the queues drain, so genuine
    // idleness only exists while arrivals are still pending: replay a
    // sparse two-minute tail (one tiny request every 10 s) and the
    // scaler must shed the burst capacity down toward min_replicas.
    let t0 = fleet.clock;
    let tail: Vec<Request> = (0..12)
        .map(|k| Request { id: 1_000_000 + k, arrival: t0 + 10.0 * (k + 1) as f64,
                           prompt_len: 12, gen_len: 4 })
        .collect();
    let report = fleet.run_trace(tail).unwrap();
    assert!(report.retires >= 1,
            "a fleet idling at 0.1 req/s must shed capacity: {report:?}");
    assert_eq!(report.completed, n + 12, "retirement stranded work");
    let serving = fleet
        .replicas
        .iter()
        .filter(|r| r.accepting())
        .count();
    assert!(serving >= 1, "retired below min_replicas");
}

/// Two replicas behind round-robin; replica 0 takes a permanent
/// interference wall at t = 6 s that leaves less than the dense
/// parameter footprint available. Round-robin keeps feeding it, so
/// without migration every in-flight sequence there is evicted and
/// every queued request burns against the wall.
fn walled_fleet(migrate: bool, seed: u64) -> Fleet {
    use rap::server::memmon::MemoryMonitor;

    let cfg = FleetConfig {
        migrate,
        // no drain/respawn: isolate migration vs local requeue
        oom_threshold: usize::MAX,
        max_sim_secs: 4000.0,
        ..FleetConfig::default()
    };
    let mut fleet = uniform_sim_fleet(2, seed, RouterPolicy::RoundRobin,
                                      cfg, slow_quiet_spec());
    let params = fleet.replicas[0].engine.bytes_used();
    let cap = params * 4;
    fleet.replicas[0].engine.monitor =
        MemoryMonitor::walls(cap, &[(6.0, 1e12, cap - params / 2)]);
    fleet
}

fn walled_trace() -> Vec<Request> {
    (0..30)
        .map(|i| Request { id: i, arrival: 0.4 * i as f64,
                           prompt_len: 16, gen_len: 24 })
        .collect()
}

#[test]
fn migration_strictly_reduces_oom_evictions() {
    let mut baseline = walled_fleet(false, 31);
    let br = baseline.run_trace(walled_trace()).unwrap();
    let mut elastic = walled_fleet(true, 31);
    let er = elastic.run_trace(walled_trace()).unwrap();

    // the wall caught in-flight work on the baseline…
    assert!(br.evictions >= 1,
            "baseline never evicted — the wall missed: {br:?}");
    // …which migration turns into live transfers
    assert!(er.evictions < br.evictions,
            "migration did not strictly reduce evictions: {} vs {}",
            er.evictions, br.evictions);
    assert_eq!(er.evictions, 0,
               "replica 1 had headroom for every victim: {er:?}");
    assert!(er.migrations >= 1, "nothing migrated: {er:?}");
    assert!(er.migration_bytes > 0);

    // saved sequences finish: strictly more completions, fewer losses
    assert!(er.completed > br.completed,
            "migration must save completions: {} vs {}", er.completed,
            br.completed);
    assert!(er.rejected < br.rejected,
            "queue rebalancing must save rejections: {} vs {}",
            er.rejected, br.rejected);
    // conservation on both runs: every arrival completed, was
    // permanently rejected, or was dropped at the router
    for r in [&br, &er] {
        assert_eq!(r.completed as u64 + r.rejected + r.dropped, 30,
                   "unaccounted sequences: {r:?}");
    }
}

#[test]
fn elastic_fleet_beats_fixed_fleet_on_burst_storm() {
    // The acceptance comparison (also reproducible via
    // `rap experiment fleet --elastic --seed 7`): same seeded
    // burst-storm trace, same replicas, same walls — fixed
    // drain/respawn vs autoscale+migration.
    let seed = 7;
    let reqs = elastic_demo_trace(seed);
    let mut fixed = elastic_demo_fleet(seed, false);
    let fr = fixed.run_trace(reqs.clone()).unwrap();
    let mut elastic = elastic_demo_fleet(seed, true);
    let er = elastic.run_trace(reqs).unwrap();

    assert!(fr.evictions >= 1,
            "walls never caught in-flight work on the baseline: {fr:?}");
    assert!(er.evictions < fr.evictions,
            "elastic fleet must evict less: {} vs {}", er.evictions,
            fr.evictions);
    assert!(er.p99_ttft < fr.p99_ttft,
            "elastic fleet must hold a lower p99 TTFT: {:.3} vs {:.3}",
            er.p99_ttft, fr.p99_ttft);
    assert!(er.completed >= fr.completed,
            "elastic fleet lost completions: {} vs {}", er.completed,
            fr.completed);
    assert!(er.migrations >= 1 || er.spawns >= 1,
            "elastic fleet never used its new powers: {er:?}");
}

#[test]
fn fleet_report_json_is_byte_identical_per_seed() {
    let run = |seed: u64| {
        let mut fleet = elastic_demo_fleet(seed, true);
        let report = fleet.run_trace(elastic_demo_trace(seed)).unwrap();
        report.to_json().pretty()
    };
    let a = run(11);
    let b = run(11);
    assert_eq!(a, b, "same seed must reproduce the report byte for byte");
    let c = run(12);
    assert_ne!(a, c, "different seeds should differ");

    // the elastic default fleet (heterogeneous, adaptive controllers,
    // seeded interference) must reproduce too
    let run_default = |seed: u64| {
        use rap::coordinator::fleet::default_sim_fleet_with;
        let cfg = FleetConfig {
            migrate: true,
            autoscale: Some(AutoscaleConfig::default()),
            max_sim_secs: 4000.0,
            ..FleetConfig::default()
        };
        let mut fleet = default_sim_fleet_with(3, seed,
                                               RouterPolicy::RapAware,
                                               cfg);
        let report =
            fleet.run_trace(burst_storm_trace(seed, 90.0)).unwrap();
        report.to_json().pretty()
    };
    assert_eq!(run_default(5), run_default(5));
}

#[test]
fn burst_storm_trace_really_storms() {
    let reqs = burst_storm_trace(42, 120.0);
    assert!(!reqs.is_empty());
    // bursts: some 6 s window is ≥ 2.5× denser than the overall mean
    // rate (the 8× burst multiplier lands near 3.5× after the mean
    // itself absorbs the bursts)
    let mean_per_6s = reqs.len() as f64 / 20.0;
    let mut best = 0usize;
    let mut t0 = 0.0;
    while t0 < 114.0 {
        let n = reqs.iter()
            .filter(|r| r.arrival >= t0 && r.arrival < t0 + 6.0)
            .count();
        best = best.max(n);
        t0 += 1.0;
    }
    assert!(best as f64 >= 2.5 * mean_per_6s,
            "no burst found: peak {best} vs mean {mean_per_6s:.1}");
}

/// The ISSUE-4 headline: interference spikes sized into the absorbable
/// band (`min_viable < Sys_avail < current`) aimed at a fleet with
/// every pressure reflex armed. Under mask-elastic accounting the
/// controllers absorb every spike — zero migrations, zero spawns, zero
/// OOMs — while the identical fleet under current-mask accounting
/// reroutes queues and spawns replicas for the same (phantom) pressure,
/// at no TTFT benefit.
#[test]
fn absorbable_spike_is_absorbed_without_migration_or_spawns() {
    let seed = 13;
    let reqs = absorbable_spike_trace(seed);
    let mut phantom = absorbable_spike_fleet(seed, false);
    let pr = phantom.run_trace(reqs.clone()).unwrap();
    let mut elastic = absorbable_spike_fleet(seed, true);
    let er = elastic.run_trace(reqs).unwrap();

    // the phantom path really fires: same walls, same trace, but the
    // current-mask accounting migrates and spawns
    assert!(pr.migrations >= 1,
            "current-mask accounting never migrated — the scenario's \
             walls missed: {pr:?}");
    assert!(pr.spawns >= 1,
            "current-mask accounting never spawned: {pr:?}");
    assert!(pr.oom_events >= 1);

    // the fix: every spike absorbed by mask-shrinking alone
    assert_eq!(er.migrations, 0,
               "mask-elastic fleet migrated for absorbable pressure: \
                {er:?}");
    assert_eq!(er.spawns, 0,
               "mask-elastic fleet spawned for absorbable pressure: \
                {er:?}");
    assert_eq!(er.oom_events, 0);
    assert!(er.absorbed_spikes >= 1,
            "no spike was charged as absorbed: {er:?}");
    assert_eq!(er.evictions, 0);

    // and absorption is not bought with latency or completions: the
    // acceptance inequality (strictly fewer migrations and spawns at
    // equal-or-better p99 TTFT)
    assert!(er.p99_ttft <= pr.p99_ttft,
            "mask-elastic p99 TTFT regressed: {:.3} vs {:.3}",
            er.p99_ttft, pr.p99_ttft);
    assert!(er.completed >= pr.completed,
            "mask-elastic fleet lost completions: {} vs {}",
            er.completed, pr.completed);
}

/// Wall-clock audit (ISSUE 4): `controller_secs` is measured with
/// `std::time::Instant` and is nondeterministic across runs, so it —
/// and every other wall-clock-derived field — must never appear in the
/// serialized report the byte-identical-per-seed tests compare. (It
/// lives in `ServeReport::wall`, a print-only section.)
#[test]
fn fleet_report_json_excludes_wall_clock_fields() {
    let mut fleet = elastic_demo_fleet(3, true);
    let report = fleet.run_trace(elastic_demo_trace(3)).unwrap();
    // the engines really did accumulate wall-clock controller time
    assert!(fleet.replicas.iter().any(|r| {
        r.engine.metrics.controller_secs > 0.0
    }));
    let json = report.to_json().pretty();
    for key in ["controller_secs", "exec_secs", "wall"] {
        assert!(!json.contains(key),
                "wall-clock-derived field '{key}' leaked into the \
                 determinism-compared JSON");
    }
}

/// Satellite (ISSUE 5): a spawned replica charges a warm-up cost — it
/// stays `Warming` for `warmup_secs` of sim time before accepting
/// routes — regression-tested on the ramp-up trace. Warm-up must delay
/// a spawn's first route without stranding any work.
#[test]
fn spawned_replicas_charge_warmup_before_serving() {
    const WARMUP: f64 = 8.0;
    let cfg = FleetConfig { warmup_secs: WARMUP, ..autoscale_cfg(2, 6) };
    let mut fleet = uniform_sim_fleet(2, 17, RouterPolicy::LeastOutstanding,
                                      cfg, slow_quiet_spec());
    let reqs = ramp_up_trace(17, 120.0);
    let n = reqs.len();
    let report = fleet.run_trace(reqs).unwrap();
    assert!(report.spawns >= 1,
            "the 12× ramp must still scale up under warm-up: {report:?}");
    // every spawned replica's first route came at least warmup_secs
    // after its spawn
    let mut checked = 0;
    for r in fleet.replicas.iter().filter(|r| r.spawned_at.is_some()) {
        let spawned = r.spawned_at.unwrap();
        if let Some(first) = r.first_routed_at {
            assert!(first >= spawned + WARMUP - 1e-9,
                    "replica {} routed at {first:.2}s after spawning at \
                     {spawned:.2}s (warm-up {WARMUP}s skipped)", r.id);
            checked += 1;
        }
    }
    assert!(checked >= 1, "no spawned replica was ever routed to");
    // warm-up delays capacity; it must not lose any of it
    assert_eq!(report.completed, n);
    assert_eq!(report.oom_events, 0);
    assert_eq!(report.evictions, 0);
}

/// Satellite (ISSUE 5): migration ships (and charges) only the live
/// `prompt + generated` KV slice, not the prefill-bucket-padded cache.
/// On the PR-3 burst-storm seed the charged bytes must be strictly
/// below what the padded accounting would have charged.
#[test]
fn migration_charges_live_slice_not_padded_cache() {
    let seed = 7; // the PR-3 acceptance seed: real mid-decode migrations
    let reqs = elastic_demo_trace(seed);
    let mut fleet = elastic_demo_fleet(seed, true);
    let report = fleet.run_trace(reqs).unwrap();
    assert!(report.migrations >= 1, "nothing migrated: {report:?}");
    assert!(report.migration_bytes > 0);
    assert_eq!(report.migration_bytes, fleet.migration_bytes);
    assert!(fleet.migration_bytes < fleet.migration_bytes_padded,
            "live-slice charging must strictly undercut the padded \
             cache: {} vs {}", fleet.migration_bytes,
            fleet.migration_bytes_padded);
}

/// Satellite (ISSUE 5, the PR-4 follow-up): `absorbed_spikes` feeds the
/// autoscaler as an early-warning signal behind
/// `AutoscaleConfig::scale_on_absorption`. Off (the default), the
/// absorbable-spike scenario keeps its zero-spawn contract; armed, the
/// same seeded absorption run scales up *before* any true OOM exists.
#[test]
fn sustained_absorption_scales_up_only_when_armed() {
    let seed = 13;
    let reqs = absorbable_spike_trace(seed);
    // default: absorption is invisible to the scaler (PR-4 contract)
    let mut off = absorbable_spike_fleet(seed, true);
    let off_report = off.run_trace(reqs.clone()).unwrap();
    assert!(off_report.absorbed_spikes >= 1,
            "the wall was never absorbed: {off_report:?}");
    assert_eq!(off_report.spawns, 0);
    assert_eq!(off_report.oom_events, 0);
    // armed: the identical run treats sustained absorption as pressure
    let base = absorbable_spike_fleet(seed, true);
    let armed_cfg = AutoscaleConfig {
        scale_on_absorption: true,
        high_absorbed_spikes: 1,
        ..base.cfg.autoscale.unwrap()
    };
    let mut armed = base.with_autoscale(armed_cfg);
    let armed_report = armed.run_trace(reqs).unwrap();
    assert!(armed_report.absorbed_spikes >= 1);
    assert!(armed_report.spawns >= 1,
            "sustained absorption never triggered the early warning: \
             {armed_report:?}");
    // the warning fires instead of, not because of, true OOMs
    assert_eq!(armed_report.oom_events, 0);
}

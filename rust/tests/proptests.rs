//! Property-style tests over the coordinator invariants (routing,
//! batching, masks, memory). The offline image has no `proptest`, so
//! cases are driven by the in-tree PRNG: hundreds of random instances per
//! property, seeded and reproducible — shrinkage is replaced by printing
//! the failing case's seed.

use rap::api::SubmitRequest;
use rap::coordinator::fleet::{default_sim_meta, uniform_sim_fleet,
                              FleetConfig};
use rap::coordinator::replica::{build_sim_replica, Replica, ReplicaSpec,
                                ReplicaState};
use rap::coordinator::router::{Router, RouterPolicy};
use rap::mask::PruneMask;
use rap::memory::{MemoryModel, Workload};
use rap::model_meta::{BlockId, ModelMeta, BYTES_PER_SCALAR};
use rap::server::batcher::{decode_bucket, prefill_bucket, ActiveSeq,
                           Batcher, DECODE_BUCKETS, PREFILL_BUCKETS};
use rap::server::kv::{KvManager, KvPolicy};
use rap::server::memmon::MemoryMonitor;
use rap::util::json::Json;
use rap::util::rng::Rng;
use rap::workload::Request;

fn rand_meta(rng: &mut Rng) -> ModelMeta {
    let n_heads = [2usize, 4, 8][rng.below(3)];
    let kv_div = [1usize, 2][rng.below(2)];
    let n_kv = (n_heads / kv_div).max(1);
    ModelMeta::synthetic("p", rng.range(1, 8), 32 * rng.range(1, 4),
                         n_heads, n_kv, 16 * rng.range(1, 8),
                         64, 32 * rng.range(1, 4))
}

fn rand_mask(meta: &ModelMeta, rng: &mut Rng) -> PruneMask {
    let mut m = PruneMask::full(meta);
    for l in 0..meta.n_layers {
        for h in 0..meta.n_heads {
            if rng.chance(0.3) {
                m.set_head(l, h, false);
            }
        }
        for c in 0..meta.d_ff {
            if rng.chance(0.3) {
                m.set_ffn_channel(l, c, false);
            }
        }
    }
    m
}

#[test]
fn prop_peak_memory_monotone_under_pruning() {
    // Removing any block never increases peak memory, for any workload.
    for seed in 0..150u64 {
        let mut rng = Rng::new(seed);
        let meta = rand_meta(&mut rng);
        let mem = MemoryModel::new(&meta);
        let w = Workload::new(rng.range(1, 17), rng.range(1, meta.max_seq));
        let mask = rand_mask(&meta, &mut rng);
        let before = mem.peak_bytes(&mask, w);
        for b in meta.all_blocks() {
            let after = mem.peak_bytes(&mask.with_block_dropped(b), w);
            assert!(after <= before, "seed {seed}: {b} grew {before} -> \
                     {after}");
        }
    }
}

#[test]
fn prop_param_fraction_in_unit_interval_and_consistent() {
    for seed in 0..150u64 {
        let mut rng = Rng::new(seed);
        let meta = rand_meta(&mut rng);
        let mask = rand_mask(&meta, &mut rng);
        let f = mask.param_fraction(&meta);
        assert!((0.0..=1.0 + 1e-9).contains(&f), "seed {seed}: {f}");
        // param_bytes must equal fraction × total (both derive from the
        // same mask but via different code paths)
        let mem = MemoryModel::new(&meta);
        let bytes = mem.param_bytes(&mask) as f64;
        let expect = f * (meta.total_params() * 4) as f64;
        assert!((bytes - expect).abs() < 1e-6 * expect.max(1.0),
                "seed {seed}: {bytes} vs {expect}");
    }
}

#[test]
fn prop_block_drop_restore_roundtrip() {
    for seed in 0..100u64 {
        let mut rng = Rng::new(seed);
        let meta = rand_meta(&mut rng);
        let full = PruneMask::full(&meta);
        let mut m = full.clone();
        let mut order = meta.all_blocks();
        rng.shuffle(&mut order);
        let k = rng.below(order.len() + 1);
        for b in &order[..k] {
            m.drop_block(*b);
        }
        assert_eq!(m.dropped_blocks().len(), k);
        for b in &order[..k] {
            m.restore_block(*b);
        }
        assert_eq!(m, full, "seed {seed}");
    }
}

#[test]
fn prop_mask_key_collision_free_on_block_masks() {
    // All single- and double-block masks of one model have distinct keys.
    let meta = ModelMeta::synthetic("k", 6, 64, 4, 2, 96, 128, 64);
    let full = PruneMask::full(&meta);
    let mut keys = std::collections::HashSet::new();
    keys.insert(full.key());
    let blocks = meta.all_blocks();
    for (i, &a) in blocks.iter().enumerate() {
        assert!(keys.insert(full.with_block_dropped(a).key()));
        for &b in &blocks[i + 1..] {
            let m = full.with_block_dropped(a).with_block_dropped(b);
            assert!(keys.insert(m.key()), "collision at {a}+{b}");
        }
    }
}

#[test]
fn prop_buckets_cover_and_bound() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed);
        let p = rng.range(1, 1000);
        let pb = prefill_bucket(p);
        assert!(PREFILL_BUCKETS.contains(&pb));
        if p <= *PREFILL_BUCKETS.last().unwrap() {
            assert!(pb >= p, "prefill bucket {pb} < prompt {p}");
            // minimality: no smaller bucket fits
            for &b in PREFILL_BUCKETS.iter() {
                if b < pb {
                    assert!(b < p);
                }
            }
        }
        let n = rng.below(40);
        let db = decode_bucket(n);
        assert!(db <= n.max(0));
        if n > 0 {
            assert!(DECODE_BUCKETS.contains(&db));
            // maximality
            for &b in DECODE_BUCKETS.iter() {
                if b > db {
                    assert!(b > n);
                }
            }
        }
    }
}

#[test]
fn prop_batcher_fcfs_and_caps() {
    for seed in 0..100u64 {
        let mut rng = Rng::new(seed);
        let mut b = Batcher::new();
        let n = rng.range(1, 30);
        for id in 0..n as u64 {
            // uniform priority: the queue must stay exactly FCFS
            b.enqueue(SubmitRequest::new(rng.range(2, 120),
                                         rng.range(2, 60))
                .with_id(id)
                .with_arrival(id as f64));
        }
        let mut last = None;
        let mut admitted = 0;
        while let Some(r) = b.pop_for_prefill() {
            if let Some(prev) = last {
                assert!(r.id > prev, "seed {seed}: FCFS violated");
            }
            last = Some(r.id);
            b.push_active(ActiveSeq { req: r, generated: 0,
                                      next_token: 0,
                                      prefill_done_at: 0.0 });
            admitted += 1;
        }
        assert!(admitted <= b.max_active);
        let ids = b.decode_ids();
        assert_eq!(ids.len(), decode_bucket(b.active.len()));
        // decode ids are the oldest actives
        for (i, id) in ids.iter().enumerate() {
            assert_eq!(*id, i as u64);
        }
    }
}

#[test]
fn prop_kv_gather_scatter_roundtrip() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed);
        let meta = rand_meta(&mut rng);
        let mask = PruneMask::full(&meta);
        let mut kv = KvManager::new(&meta);
        let n_seqs = rng.range(1, 6);
        let elems = kv.seq_elems();
        for id in 0..n_seqs as u64 {
            let fill = id as f32 + 1.0;
            kv.insert(id, vec![fill; elems], vec![-fill; elems],
                      rng.range(1, meta.max_seq / 2), &mask)
                .unwrap();
        }
        let ids: Vec<u64> = (0..n_seqs as u64).collect();
        let lens_before: Vec<usize> =
            ids.iter().map(|i| kv.seq_len(*i).unwrap()).collect();
        let (k, v) = kv.gather(&ids).unwrap();
        // scatter_cache alone must not change lengths
        kv.scatter_cache(&ids, &k, &v, false).unwrap();
        for (i, id) in ids.iter().enumerate() {
            assert_eq!(kv.seq_len(*id).unwrap(), lens_before[i]);
        }
        // round-trip preserves contents
        let (k2, v2) = kv.gather(&ids).unwrap();
        assert_eq!(k, k2, "seed {seed}");
        assert_eq!(v, v2);
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.f64() * 2000.0 - 1000.0).round()),
            3 => {
                let n = rng.below(12);
                Json::Str((0..n).map(|_| {
                    let c = [b'a', b'Z', b'"', b'\\', b'\n', 0xC3u8]
                        [rng.below(5)]; // skip raw 0xC3 half-char
                    c as char
                }).collect())
            }
            4 => Json::Arr((0..rng.below(5))
                .map(|_| gen(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.below(5) {
                    m.insert(format!("k{i}"), gen(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    for seed in 0..300u64 {
        let mut rng = Rng::new(seed);
        let v = gen(&mut rng, 3);
        let parsed = Json::parse(&v.dumps()).unwrap();
        assert_eq!(parsed, v, "seed {seed}: {}", v.dumps());
        let pretty = Json::parse(&v.pretty()).unwrap();
        assert_eq!(pretty, v, "seed {seed} (pretty)");
    }
}

/// Random replicas in random lifecycle states with random memory walls.
fn random_fleet_replicas(rng: &mut Rng, n: usize, seed: u64)
                         -> Vec<Replica> {
    let meta = default_sim_meta();
    (0..n)
        .map(|i| {
            let mut r = build_sim_replica(
                i, &meta, &ReplicaSpec::heterogeneous(i), seed);
            // random interference: hold a random slice of capacity
            let cap = r.engine.monitor.cfg.capacity;
            let held = rng.below(cap);
            r.engine.monitor =
                MemoryMonitor::walls(cap, &[(0.0, 1e12, held)]);
            match rng.below(5) {
                0 => r.state = ReplicaState::Draining,
                1 => r.state = ReplicaState::Respawning { until: 1e9 },
                2 => r.state = ReplicaState::Retired,
                _ => {}
            }
            r
        })
        .collect()
}

#[test]
fn prop_router_only_picks_accepting_replicas() {
    // Every routed request lands on a live, accepting replica — under
    // every policy, any lifecycle mix, and any memory weather. None is
    // returned only when truly no replica accepts.
    for seed in 0..80u64 {
        let mut rng = Rng::new(seed);
        let n = rng.range(1, 6);
        let reps = random_fleet_replicas(&mut rng, n, seed);
        let policy = RouterPolicy::ALL[rng.below(RouterPolicy::ALL.len())];
        let mut router = Router::new(policy, n);
        let t = rng.f64() * 50.0;
        for k in 0..16u64 {
            let req = SubmitRequest::new(rng.range(2, 120),
                                         rng.range(2, 48))
                .with_id(1000 + k)
                .with_arrival(t);
            match router.route(&req, &reps, t) {
                Some(i) => assert!(
                    reps[i].accepting(),
                    "seed {seed}: {:?} routed to a non-accepting \
                     replica {i} ({})", policy, reps[i].state.name()),
                None => assert!(
                    reps.iter().all(|r| !r.accepting()),
                    "seed {seed}: {:?} dropped a request while a \
                     replica was accepting", policy),
            }
        }
        // histogram only counts placed requests
        let placed: u64 = router.decisions.iter().sum();
        assert!(placed <= 16);
    }
}

#[test]
fn prop_kv_headroom_router_maximizes_elastic_headroom() {
    // The kv-headroom policy never picks a replica with less *elastic*
    // headroom (Sys_avail − min-viable footprint, the memory outlook)
    // than an available alternative.
    for seed in 0..80u64 {
        let mut rng = Rng::new(seed ^ 0xABCD);
        let n = rng.range(2, 6);
        let reps = random_fleet_replicas(&mut rng, n, seed);
        let mut router = Router::new(RouterPolicy::KvHeadroom, n);
        let t = rng.f64() * 50.0;
        let req = SubmitRequest::new(16, 8).with_id(1).with_arrival(t);
        if let Some(pick) = router.route(&req, &reps, t) {
            let picked = reps[pick].elastic_headroom(t);
            for (i, r) in reps.iter().enumerate() {
                if r.accepting() {
                    assert!(picked >= r.elastic_headroom(t),
                            "seed {seed}: picked {pick} with {picked} \
                             but replica {i} had {}",
                            r.elastic_headroom(t));
                }
            }
        }
    }
}

#[test]
fn prop_rap_router_never_prefers_infeasible() {
    // The rap-aware score must rank every feasible replica (elastic
    // headroom > request cost) above every infeasible one, regardless
    // of mask utility or queue depth — the naive `utility × (headroom −
    // cost)` score inverts that when headroom < cost, because high
    // utility shrinks the *penalty*. Among infeasible-only fleets the
    // least-underwater replica must win.
    for seed in 0..120u64 {
        let mut rng = Rng::new(seed ^ 0xFEA51B1E);
        let n = rng.range(2, 6);
        let mut reps = random_fleet_replicas(&mut rng, n, seed);
        // random mask damage so utilities differ (whole blocks, like
        // the controller's action space)
        let meta = default_sim_meta();
        for r in &mut reps {
            for b in meta.all_blocks() {
                if rng.chance(0.35) {
                    r.engine.mask.drop_block(b);
                }
            }
        }
        let t = rng.f64() * 50.0;
        let req = SubmitRequest::new(rng.range(2, 120),
                                     rng.range(2, 48))
            .with_id(1)
            .with_arrival(t);
        let mut router = Router::new(RouterPolicy::RapAware, n);
        let Some(pick) = router.route(&req, &reps, t) else {
            continue;
        };
        let feasible = |r: &Replica| {
            r.elastic_headroom(t) as f64
                > r.engine.elastic_admission_cost(&req) as f64
        };
        let any_feasible =
            reps.iter().any(|r| r.accepting() && feasible(r));
        if any_feasible {
            assert!(feasible(&reps[pick]),
                    "seed {seed}: picked infeasible replica {pick} \
                     while a feasible one existed");
        } else {
            // all infeasible: the pick minimizes the deficit
            let deficit = |r: &Replica| {
                r.engine.elastic_admission_cost(&req) as f64
                    - r.elastic_headroom(t) as f64
            };
            let picked = deficit(&reps[pick]);
            for (i, r) in reps.iter().enumerate() {
                if r.accepting() {
                    assert!(picked <= deficit(r) + 1e-9,
                            "seed {seed}: picked {pick} (deficit \
                             {picked}) over less-underwater {i} \
                             ({})", deficit(r));
                }
            }
        }
    }
}

#[test]
fn prop_migration_conserves_sequences() {
    // Random traces through a walled elastic fleet: migration must
    // never duplicate or drop a sequence. After the run drains, every
    // trace id is accounted for exactly once — completed somewhere,
    // permanently rejected, or dropped at the router — and no id
    // completes twice.
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed ^ 0x51AB);
        let cfg = FleetConfig {
            migrate: true,
            oom_threshold: usize::MAX,
            max_sim_secs: 4000.0,
            ..FleetConfig::default()
        };
        let spec = ReplicaSpec {
            flops_per_sec: 1.0e8,
            app_rate: 0.0,
            adaptive: false,
            ..ReplicaSpec::heterogeneous(0)
        };
        let mut fleet = uniform_sim_fleet(3, seed,
                                          RouterPolicy::RoundRobin, cfg,
                                          spec);
        // replica 0 hits a wall mid-run: less than the dense footprint
        let params = fleet.replicas[0].engine.bytes_used();
        let cap = params * 4;
        fleet.replicas[0].engine.monitor =
            MemoryMonitor::walls(cap, &[(4.0, 1e12, cap - params / 2)]);
        let n = rng.range(10, 40) as u64;
        let reqs: Vec<Request> = (0..n)
            .map(|id| Request { id, arrival: rng.f64() * 20.0,
                                prompt_len: rng.range(2, 120),
                                gen_len: rng.range(2, 48) })
            .collect();
        let report = fleet.run_trace(reqs).unwrap();
        let mut seen = std::collections::HashSet::new();
        let mut completed = 0u64;
        for r in &fleet.replicas {
            for rec in &r.engine.metrics.completed {
                assert!(seen.insert(rec.id),
                        "seed {seed}: sequence {} completed twice",
                        rec.id);
                assert!(rec.id < n, "seed {seed}: unknown id {}", rec.id);
                completed += 1;
            }
        }
        let rejected: u64 = fleet
            .replicas
            .iter()
            .map(|r| r.engine.metrics.rejected)
            .sum();
        assert_eq!(completed + rejected + report.dropped, n,
                   "seed {seed}: sequences unaccounted for: {report:?}");
        // the run drained: nothing is still queued, active, or parked
        for r in &fleet.replicas {
            assert_eq!(r.engine.outstanding(), 0, "seed {seed}");
            assert_eq!(r.engine.parked_len(), 0, "seed {seed}");
        }
    }
}

#[test]
fn prop_gsi_greedy_never_worse_than_one_shot_additive() {
    use rap::gsi::GsiEngine;
    use rap::runtime::{NllEvaluator, SyntheticEvaluator};
    // Under an additive-damage model both orderings coincide; with layer
    // synergy greedy must be ≤ one-shot in final NLL.
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed);
        let n_layers = rng.range(2, 6);
        let meta = ModelMeta::synthetic("g", n_layers, 64, 4, 2, 96, 128,
                                        64);
        let damage: Vec<f64> =
            (0..2 * n_layers).map(|_| rng.f64()).collect();
        let synergy = rng.f64() * 3.0;
        let mut ev = SyntheticEvaluator::new(meta.clone(), 2.0,
                                             damage.clone(), synergy);
        let n_remove = rng.range(1, 2 * n_layers);
        let mut gsi = GsiEngine::new(&mut ev);
        let full = PruneMask::full(&meta);
        let os = gsi.one_shot_order(&full).unwrap();
        let mut os_mask = full.clone();
        for (b, _) in os.iter().take(n_remove) {
            os_mask.drop_block(*b);
        }
        let os_nll = gsi.nll(&os_mask).unwrap();
        let mut cnt = 0;
        let g = gsi.greedy(&full, |_| {
            cnt += 1;
            cnt > n_remove
        }).unwrap();
        let g_nll = *g.nll_after.last().unwrap();
        assert!(g_nll <= os_nll + 1e-9,
                "seed {seed}: greedy {g_nll} > one-shot {os_nll}");
        drop(gsi);
        let _ = ev.eval_nll(&full);
    }
}

/// ISSUE-6 conservation property: a sequence whose cross-replica
/// transfer is interrupted mid-flight must end up exactly once —
/// restored on the destination, requeued at the source, or terminally
/// rejected — never both, never neither. Each case crashes a replica
/// (launching checkpoint-restore transfers) inside a partition window
/// that interrupts them, with a degrade window stretching flight times
/// so some transfers are caught mid-air; the partition length varies so
/// across seeds transfers exhaust their retries (local-requeue
/// fallback) or survive them (late delivery).
#[test]
fn prop_interrupted_transfers_deliver_exactly_once() {
    use rap::api::RequestStatus;
    use rap::runtime::{FaultEvent, FaultPlan};

    for seed in 0..12u64 {
        let mut rng = Rng::new(seed ^ 0xC4A05);
        let crash_at = 2.0 + 4.0 * rng.f64();
        let part_from = crash_at - 0.25;
        let part_until = crash_at + 0.5 + 2.5 * rng.f64();
        let plan = FaultPlan::new(vec![
            FaultEvent::Degrade {
                from: 0.0,
                until: part_from,
                factor: 1.5 + 6.0 * rng.f64(),
            },
            FaultEvent::Crash { at: crash_at, replica: 1 },
            FaultEvent::Partition { from: part_from, until: part_until },
        ]);
        let spec = ReplicaSpec {
            flops_per_sec: 1.0e8, // slow: decodes live at crash time
            app_rate: 0.0,
            adaptive: false,
            capacity_mult: 2.5,
            ..ReplicaSpec::heterogeneous(0)
        };
        let cfg = FleetConfig {
            migrate: true,
            oom_threshold: usize::MAX,
            checkpoint_period_secs: Some(0.5),
            max_sim_secs: 4000.0,
            ..FleetConfig::default()
        };
        let mut fleet = uniform_sim_fleet(
            2, seed, RouterPolicy::LeastOutstanding, cfg, spec)
            .with_fault_plan(plan);
        let n = rng.range(12, 30) as u64;
        let mut reqs: Vec<SubmitRequest> = (0..n)
            .map(|id| {
                SubmitRequest::new(rng.range(8, 64), rng.range(8, 40))
                    .with_id(id)
                    .with_arrival(rng.f64() * crash_at)
            })
            .collect();
        reqs.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        let mut handles = Vec::new();
        let mut next = 0usize;
        let mut t = 0.0;
        while next < reqs.len() || t < part_until + 1.0 {
            t += 0.25;
            fleet.step(t).unwrap();
            while next < reqs.len() && reqs[next].arrival <= t {
                handles.push(fleet.submit(reqs[next].clone()));
                next += 1;
            }
            // the location index must agree with the exhaustive scan
            // *mid-flight* too — while ids are genuinely Queued,
            // Running, and Migrating across crashes and partitions
            for h in &handles {
                assert_eq!(fleet.poll(*h), fleet.poll_scan(*h),
                           "seed {seed}: poll index diverged \
                            mid-run for id {}", h.id);
            }
        }
        fleet.step(t + 600.0).unwrap();
        // the scenario has teeth: the crash actually launched restores
        let r = fleet.report();
        assert!(r.chaos.crashes >= 1, "seed {seed}: crash never landed");
        // every id is terminal (a still-in-flight transfer would poll
        // Migrating, a stranded requeue would poll Queued/Active) ...
        for h in &handles {
            match fleet.poll(*h) {
                Some(RequestStatus::Finished(_)) => {}
                other => panic!(
                    "seed {seed}: id {} not terminal at drain: {other:?}",
                    h.id),
            }
            // ... and the O(1) location index survived the same
            // crash/restore/requeue churn: it must agree with the
            // exhaustive backlog → transfers → replicas scan
            assert_eq!(fleet.poll(*h), fleet.poll_scan(*h),
                       "seed {seed}: poll index diverged from the \
                        scan for id {}", h.id);
        }
        // ... and holds exactly one terminal outcome across the fleet:
        // two bookings would mean a duplicated restore, zero a request
        // silently dropped (ingress-terminal ids book zero replica
        // outcomes but already polled Finished above)
        for id in 0..n {
            let bookings = fleet
                .replicas
                .iter()
                .filter(|r| r.engine.metrics.outcome(id).is_some())
                .count();
            assert!(bookings <= 1,
                    "seed {seed}: id {id} booked {bookings} terminal \
                     outcomes — duplicated by recovery");
        }
        // fleet-level conservation closes the loop
        assert_eq!(r.completed as u64 + r.rejected + r.cancelled
                       + r.deadline_missed + r.dropped,
                   n,
                   "seed {seed}: arrivals unaccounted: {r:?}");
    }
}

/// PR-9 accounting oracle: the KV manager's incremental per-class
/// books (and the O(classes) byte formulas built on them) must match
/// an exhaustive per-sequence oracle after *any* interleaving of
/// insert / decode-bump / compress / evict / floor change. The oracle
/// here is computed from the public per-sequence surface (`seq_len`,
/// `policy_of`) and first principles (`active_kv_groups` × head_dim ×
/// `BYTES_PER_SCALAR`), deliberately not through the manager's own
/// per-token pricing helpers; `audit()` separately cross-checks the
/// incremental class totals against `rescan_classes`.
#[test]
fn prop_kv_incremental_accounting_matches_exhaustive_oracle() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed ^ 0xACC0);
        let meta = rand_meta(&mut rng);
        let mask = rand_mask(&meta, &mut rng);
        let mut kv = KvManager::new(&meta);
        let floors = [
            None,
            Some(KvPolicy::WindowSink { sink: 4, recent: 48 }),
            Some(KvPolicy::WindowSink { sink: 0, recent: 8 }),
            Some(KvPolicy::HeadDrop { keep_groups: 1 }),
        ];
        kv.set_floor(floors[rng.below(floors.len())]);
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..40 {
            match rng.below(5) {
                0 | 1 => {
                    // admit a fresh dense sequence
                    let len = rng.range(1, meta.max_seq);
                    let e = kv.seq_elems();
                    kv.insert(next_id, vec![0.0; e], vec![0.0; e], len,
                              &mask)
                        .unwrap();
                    live.push(next_id);
                    next_id += 1;
                }
                2 => {
                    // decode-bump a random subset (never past max_seq)
                    let ids: Vec<u64> = live
                        .iter()
                        .copied()
                        .filter(|&id| {
                            kv.seq_len(id).unwrap() < meta.max_seq
                                && rng.chance(0.7)
                        })
                        .collect();
                    if !ids.is_empty() {
                        kv.bump_lens(&ids, &mask).unwrap();
                    }
                }
                3 => {
                    // compress a random resident to a random policy
                    // (WindowSink, HeadDrop, or an idempotent Dense
                    // re-apply) — composition with whatever class it
                    // already carries is the interesting part
                    if let Some(&id) =
                        live.get(rng.below(live.len().max(1)))
                    {
                        let pol = match rng.below(3) {
                            0 => KvPolicy::WindowSink {
                                sink: rng.below(5),
                                recent: 1 + rng.below(60),
                            },
                            1 => KvPolicy::HeadDrop {
                                keep_groups:
                                    1 + rng.below(meta.n_kv_heads),
                            },
                            _ => KvPolicy::Dense,
                        };
                        kv.compress(id, pol).unwrap();
                    }
                }
                _ => {
                    // evict
                    if !live.is_empty() {
                        let i = rng.below(live.len());
                        let id = live.swap_remove(i);
                        assert!(kv.remove(id).is_some(), "seed {seed}");
                    }
                }
            }
            if rng.chance(0.15) {
                kv.set_floor(floors[rng.below(floors.len())]);
            }

            // incremental class totals == exhaustive rescan
            kv.audit().unwrap_or_else(|e| panic!("seed {seed}: {e}"));

            // independent byte oracle over the public per-seq surface
            let per_tok = |group_cap: usize| -> usize {
                (0..meta.n_layers)
                    .map(|l| {
                        2 * mask.active_kv_groups(l).min(group_cap)
                            * meta.head_dim() * BYTES_PER_SCALAR
                    })
                    .sum()
            };
            let mut want_tokens = 0usize;
            let mut want_used = 0usize;
            let mut want_floor = 0usize;
            for &id in &live {
                let len = kv.seq_len(id).unwrap();
                let pol = kv.policy_of(id).unwrap();
                want_tokens += len;
                want_used += len * per_tok(pol.group_cap());
                want_floor += match kv.floor() {
                    None => len * per_tok(pol.group_cap()),
                    Some(f) => len.min(f.token_cap())
                        * per_tok(pol.group_cap().min(f.group_cap())),
                };
            }
            assert_eq!(kv.len(), live.len(), "seed {seed}");
            assert_eq!(kv.total_tokens(), want_tokens, "seed {seed}");
            assert_eq!(kv.bytes_used(&mask), want_used, "seed {seed}");
            assert_eq!(kv.floor_bytes(&mask), want_floor, "seed {seed}");
        }
    }
}

/// PR-9 conservation property: in-place compression racing the rest of
/// the lifecycle — eviction under true OOM, mid-run cancels of
/// possibly-compressed residents, shed-migration of compressed caches,
/// and a crash whose checkpoint restore lands *on* the pressured
/// replica — must never leak or double-book a sequence or a KV byte.
/// Each seed walls replica 0 at a random depth (some depths the joint
/// lattice absorbs by compressing, some force a true-OOM shed), cancels
/// a random subset mid-storm, and in half the seeds crashes replica 1
/// mid-wall so checkpointed (possibly compressed) caches restore into
/// the pressure. After the drain: every id terminal exactly once, the
/// books close, and every engine's KV manager is empty with its
/// incremental accounting still matching the rescan.
#[test]
fn prop_compression_conserves_sequences_and_kv_bytes() {
    use rap::api::RequestStatus;
    use rap::runtime::{FaultEvent, FaultPlan};

    let mut pressured_runs = 0usize;
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed ^ 0xE1A5);
        let wall_at = 8.0 + 4.0 * rng.f64();
        let wall_until = wall_at + 6.0 + 4.0 * rng.f64();
        let avail_frac = 0.35 + 0.5 * rng.f64();
        let crash = seed % 2 == 0;
        let crash_at = wall_at + 0.5 + 1.5 * rng.f64();

        let spec = ReplicaSpec {
            flops_per_sec: 6.0e8, // slow: residents live through the wall
            app_rate: 0.0,
            adaptive: true,
            capacity_mult: 2.5,
            ..ReplicaSpec::heterogeneous(0)
        };
        let cfg = FleetConfig {
            migrate: true,
            oom_threshold: usize::MAX,
            elastic_accounting: true,
            kv_elastic: true,
            checkpoint_period_secs: crash.then_some(0.5),
            max_sim_secs: 4000.0,
            ..FleetConfig::default()
        };
        let mut fleet = uniform_sim_fleet(
            2, seed, RouterPolicy::LeastOutstanding, cfg, spec);
        for r in &mut fleet.replicas {
            // one controller decision up front, then only
            // pressure-triggered runs — the wall meets the deployed
            // mask, not a freshly re-tuned one
            r.engine.cfg.controller_period = 30.0;
        }
        if crash {
            fleet = fleet.with_fault_plan(FaultPlan::new(vec![
                FaultEvent::Crash { at: crash_at, replica: 1 },
            ]));
        }
        let params = fleet.replicas[0].engine.bytes_used();
        let cap = fleet.replicas[0].engine.monitor.cfg.capacity;
        let avail = (params as f64 * avail_frac) as usize;
        fleet.replicas[0].engine.monitor =
            MemoryMonitor::walls(cap,
                                 &[(wall_at, wall_until, cap - avail)]);

        // long-context arrivals, all in flight before the wall lands
        let n = rng.range(8, 16) as u64;
        let mut reqs: Vec<SubmitRequest> = (0..n)
            .map(|id| {
                SubmitRequest::new(rng.range(60, 140),
                                   rng.range(30, 90))
                    .with_id(id)
                    .with_arrival(rng.f64() * (wall_at - 1.0))
            })
            .collect();
        reqs.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        // cancel ~a quarter of them mid-wall, racing compression
        let mut cancels: Vec<(f64, u64)> = Vec::new();
        for id in 0..n {
            if rng.chance(0.25) {
                cancels.push((wall_at + rng.f64() * 4.0, id));
            }
        }
        cancels.sort_by(|a, b| a.0.total_cmp(&b.0));

        let mut handles = Vec::new();
        let mut next = 0usize;
        let mut next_cancel = 0usize;
        let mut t = 0.0;
        while next < reqs.len() || t < wall_until + 1.0 {
            t += 0.25;
            fleet.step(t).unwrap();
            while next < reqs.len() && reqs[next].arrival <= t {
                handles.push(fleet.submit(reqs[next].clone()));
                next += 1;
            }
            while next_cancel < cancels.len()
                && cancels[next_cancel].0 <= t
            {
                let id = cancels[next_cancel].1;
                let _ = fleet
                    .cancel(rap::api::RequestHandle { id })
                    .unwrap();
                next_cancel += 1;
            }
            // the incremental KV books must hold *mid-race*, on every
            // replica, in release builds too
            for r in &fleet.replicas {
                r.engine.kv.audit().unwrap_or_else(
                    |e| panic!("seed {seed} t {t}: {e}"));
            }
        }
        fleet.step(t + 600.0).unwrap();

        let r = fleet.report();
        if crash {
            assert!(r.chaos.crashes >= 1,
                    "seed {seed}: crash never landed");
        }
        if r.compressed_spikes + r.oom_events > 0 {
            pressured_runs += 1;
        }
        // every submitted id is terminal ...
        for h in &handles {
            assert!(matches!(fleet.poll(*h),
                             Some(RequestStatus::Finished(_))),
                    "seed {seed}: id {} not terminal after drain",
                    h.id);
        }
        // ... the books close ...
        assert_eq!(r.completed as u64 + r.rejected + r.cancelled
                       + r.deadline_missed + r.dropped,
                   n,
                   "seed {seed}: arrivals unaccounted: {r:?}");
        // ... and no replica leaked a sequence or a KV byte
        for rep in &fleet.replicas {
            assert_eq!(rep.engine.outstanding(), 0, "seed {seed}");
            assert_eq!(rep.engine.parked_len(), 0, "seed {seed}");
            assert!(rep.engine.kv.is_empty(),
                    "seed {seed}: {} caches leaked after drain",
                    rep.engine.kv.len());
            rep.engine.kv.audit().unwrap_or_else(
                |e| panic!("seed {seed}: {e}"));
        }
    }
    // teeth: the wall actually pressured the joint lattice somewhere
    // across the seed sweep (absorbed-by-compression or true-OOM shed)
    assert!(pressured_runs >= 1,
            "no seed ever pressured the walled replica — the race \
             scenario lost its teeth");
}

//! Long-context storm harness (PR-9): the joint (mask × KV policy)
//! acceptance surface. One seeded storm whose mid-run interference
//! wall is sized into the *joint-only* band — when it lands, both
//! lattices absorb by deploying the min-viable mask, but the closed
//! cohort's decode growth then pushes the resident KV bill past what
//! the mask axis alone can cover. Three classes of assertion:
//!
//!   (a) the decisive comparison, per seed: the mask-only fleet
//!       true-OOMs (sheds work into migrations / OOM-driven spawns)
//!       while the joint fleet compresses residents to the KV floor
//!       and absorbs in place — zero migrations, zero spawns, zero
//!       OOMs, compression engaged — at an equal-or-better p99 TTFT
//!       and no fewer completions;
//!   (b) quality: the compression floor's MCQ cost, measured by the
//!       oracle scorer over retained context positions, stays within
//!       `MCQ_EPSILON` of dense on every task — including the one
//!       whose context genuinely exceeds the floor's token cap;
//!   (c) determinism: the acceptance surface's `FleetReport` JSON is
//!       byte-identical across two runs at the same seed, for both
//!       arms.
//!
//! The storm seeds are pinned: the joint-only band is a property of
//! where the controller's greedy path lands relative to the wall, so
//! each pinned seed is one verified trajectory through it (seed 42 is
//! the one CI smokes).

use rap::corpus::Corpus;
use rap::coordinator::fleet::{longctx_storm_fleet, longctx_storm_trace};
use rap::evalharness::mcq;
use rap::server::controller::default_kv_floor;
use rap::server::kv::KvPolicy;

/// The pinned acceptance seeds. Each sits in the joint-only band:
/// mask-only sheds, joint absorbs with compression.
const LONGCTX_SEEDS: [u64; 3] = [42, 10, 100];

#[test]
fn joint_lattice_absorbs_what_mask_only_cannot() {
    for seed in LONGCTX_SEEDS {
        let reqs = longctx_storm_trace(seed);
        let mut masked = longctx_storm_fleet(seed, false);
        let mr = masked.run_trace(reqs.clone()).unwrap();
        let mut joint = longctx_storm_fleet(seed, true);
        let jr = joint.run_trace(reqs).unwrap();

        // mask-only: the wall's second pressure instant is a true OOM
        // — the min-viable mask's own KV bill crossed avail — and the
        // park/migrate machinery churns
        assert!(mr.oom_events >= 1,
                "seed {seed}: mask-only fleet absorbed the joint-only \
                 wall ({} OOMs)", mr.oom_events);
        assert!(mr.migrations + mr.spawns >= 1,
                "seed {seed}: mask-only fleet shed no work \
                 (migrations {}, spawns {})",
                mr.migrations, mr.spawns);
        assert_eq!(mr.compressed_spikes, 0,
                   "seed {seed}: mask-only fleet compressed");

        // joint: same wall, absorbed in place by compressing residents
        // to the floor — nothing moves, nothing spawns, nothing OOMs
        assert_eq!(jr.migrations, 0,
                   "seed {seed}: joint fleet migrated");
        assert_eq!(jr.spawns, 0, "seed {seed}: joint fleet spawned");
        assert_eq!(jr.oom_events, 0, "seed {seed}: joint fleet OOMed");
        assert_eq!(jr.evictions, 0, "seed {seed}: joint fleet evicted");
        assert!(jr.compressed_spikes >= 1,
                "seed {seed}: joint fleet absorbed without engaging \
                 compression");
        assert!(jr.kv_bytes_reclaimed > 0,
                "seed {seed}: compression engaged but reclaimed no \
                 bytes");
        assert!(jr.absorbed_spikes >= 1,
                "seed {seed}: joint fleet booked no absorbed spikes");

        // and the joint fleet pays nothing for it on the tail
        assert!(jr.p99_ttft <= mr.p99_ttft,
                "seed {seed}: joint p99 TTFT {} worse than mask-only {}",
                jr.p99_ttft, mr.p99_ttft);
        assert!(jr.completed >= mr.completed,
                "seed {seed}: joint completed {} < mask-only {}",
                jr.completed, mr.completed);
    }
}

/// The quality leg of the acceptance criterion: compressing to the
/// floor must not move MCQ accuracy by more than `MCQ_EPSILON` on any
/// task. The stock tasks fit under the floor's token cap (trivially
/// lossless); `longctx_task` genuinely evicts mid-context tokens, and
/// the floor's recent window still covers every position the scorer's
/// copy mechanism references — so the delta is exactly zero there too.
#[test]
fn compression_floor_holds_mcq_accuracy_within_epsilon() {
    let corpus = Corpus::synthetic(64, 7);
    let floor = default_kv_floor();
    let mut tasks = mcq::all_tasks();
    tasks.push(mcq::longctx_task());
    for seed in LONGCTX_SEEDS {
        for task in &tasks {
            let dense = mcq::policy_accuracy(&corpus, task,
                                             KvPolicy::Dense, 40, seed);
            let comp = mcq::policy_accuracy(&corpus, task, floor, 40,
                                            seed);
            assert!((dense - comp).abs() <= mcq::MCQ_EPSILON,
                    "seed {seed}, task {}: floor accuracy {comp} vs \
                     dense {dense} exceeds epsilon {}",
                    task.name, mcq::MCQ_EPSILON);
        }
    }
}

/// Two full runs at the same seed serialize to byte-identical report
/// JSON — the acceptance artifact CI uploads carries no wall-clock or
/// allocation-order residue, for either arm.
#[test]
fn longctx_report_json_is_byte_identical_per_seed() {
    for kv_elastic in [false, true] {
        let run = |seed: u64| {
            let mut fleet = longctx_storm_fleet(seed, kv_elastic);
            fleet.run_trace(longctx_storm_trace(seed)).unwrap()
                 .to_json().pretty()
        };
        let a = run(42);
        let b = run(42);
        assert_eq!(a, b,
                   "kv_elastic={kv_elastic}: report JSON differs \
                    across identical runs");
        // and it is genuinely seed-sensitive, not a constant
        let c = run(10);
        assert_ne!(a, c,
                   "kv_elastic={kv_elastic}: reports at different \
                    seeds are identical");
    }
}

//! Event-driven fleet acceptance (ISSUE 8).
//!
//! The tentpole contract: flipping `FleetConfig::event_driven` — the
//! priority-queue scheduler that steps only the due replicas — must
//! not move a single byte of any seeded report vs the lockstep full
//! sweep, on every scenario family (PR-3 elastic, PR-4 absorbable,
//! PR-5 tenant storm, PR-6 chaos storm). Plus: the event-queue
//! tie-break stays deterministic under autoscaled spawn ids, and the
//! ingress accounting fixed alongside the refactor conserves requests
//! (submitted == terminal outcomes + pending) through the paths the
//! old `routed + dropped` bookkeeping missed — cancel-from-backlog
//! and drain-with-backlog.

use rap::api::{Outcome, RequestHandle, RequestStatus, SubmitRequest};
use rap::coordinator::fleet::{absorbable_spike_fleet,
                              absorbable_spike_trace,
                              chaos_storm_fleet, chaos_storm_trace,
                              elastic_demo_fleet, elastic_demo_trace,
                              longctx_storm_fleet, longctx_storm_trace,
                              tenant_storm_fleet, tenant_storm_trace,
                              Fleet};
use rap::coordinator::metrics::FleetReport;
use rap::coordinator::router::RouterPolicy;

fn lockstep(mut fleet: Fleet) -> Fleet {
    assert!(fleet.cfg.event_driven, "event mode must be the default");
    fleet.cfg.event_driven = false;
    fleet
}

fn assert_conserved(r: &FleetReport, pending: u64, label: &str) {
    let terminal = r.completed as u64 + r.rejected + r.cancelled
        + r.deadline_missed + r.dropped;
    assert_eq!(r.total_requests, terminal + pending,
               "{label}: submitted {} != {terminal} terminal + \
                {pending} pending",
               r.total_requests);
}

/// The equivalence matrix: every seeded scenario family under both
/// `event_driven` settings produces byte-identical report JSON — and
/// each report conserves requests (every run here drains fully, so
/// pending is 0).
#[test]
fn event_driven_matches_lockstep_on_every_scenario_family() {
    let matrix: Vec<(&str, Box<dyn Fn(bool) -> FleetReport>)> = vec![
        ("elastic", Box::new(|ev| {
            let f = elastic_demo_fleet(7, true);
            let mut f = if ev { f } else { lockstep(f) };
            f.run_trace(elastic_demo_trace(7)).unwrap()
        })),
        ("absorbable", Box::new(|ev| {
            let f = absorbable_spike_fleet(13, true);
            let mut f = if ev { f } else { lockstep(f) };
            f.run_trace(absorbable_spike_trace(13)).unwrap()
        })),
        ("tenant-storm", Box::new(|ev| {
            let f = tenant_storm_fleet(42, RouterPolicy::TenantFair);
            let mut f = if ev { f } else { lockstep(f) };
            f.run_requests(tenant_storm_trace(42)).unwrap()
        })),
        ("chaos-storm", Box::new(|ev| {
            let f = chaos_storm_fleet(42, true);
            let mut f = if ev { f } else { lockstep(f) };
            f.run_requests(chaos_storm_trace(42)).unwrap()
        })),
        ("chaos-storm-nockpt", Box::new(|ev| {
            let f = chaos_storm_fleet(42, false);
            let mut f = if ev { f } else { lockstep(f) };
            f.run_requests(chaos_storm_trace(42)).unwrap()
        })),
        // PR-9: the long-context storm with the KV-compression leg
        // engaged — the scheduler refactor must not move the pressure
        // path's compress step either
        ("longctx-joint", Box::new(|ev| {
            let f = longctx_storm_fleet(42, true);
            let mut f = if ev { f } else { lockstep(f) };
            f.run_trace(longctx_storm_trace(42)).unwrap()
        })),
        ("longctx-mask-only", Box::new(|ev| {
            let f = longctx_storm_fleet(42, false);
            let mut f = if ev { f } else { lockstep(f) };
            f.run_trace(longctx_storm_trace(42)).unwrap()
        })),
    ];
    for (label, run) in &matrix {
        let event = run(true);
        let lock = run(false);
        assert_eq!(event.to_json().pretty(), lock.to_json().pretty(),
                   "{label}: event-driven report diverged from \
                    lockstep");
        assert_conserved(&event, 0, label);
    }
}

/// Same seed, two event-driven runs → byte-identical reports even
/// when the run autoscales (spawned replicas enter the event queue
/// mid-run, so their ids exercise the (time, replica, seq) tie-break);
/// a different seed diverges, so the pin is real.
#[test]
fn event_queue_tie_break_is_deterministic_under_spawns() {
    let run = |seed| {
        let mut fleet = chaos_storm_fleet(seed, true);
        let report = fleet.run_requests(chaos_storm_trace(seed))
            .unwrap();
        (fleet.replicas.len(), report.to_json().pretty())
    };
    let (roster, a) = run(42);
    assert!(roster > 3,
            "chaos storm no longer spawns a replacement — the \
             tie-break is not exercised");
    assert_eq!(a, run(42).1,
               "same seed produced different event-driven reports");
    assert_ne!(a, run(7).1,
               "different seeds produced identical reports");
}

/// Cancel-from-backlog: a request cancelled out of the tenant-fair
/// ingress backlog was submitted but never routed — under the old
/// `routed + dropped` accounting it vanished from `total_requests`.
/// It must now appear as a terminal cancel, and the books must close.
#[test]
fn conservation_holds_through_cancel_from_backlog() {
    let mut fleet = tenant_storm_fleet(42, RouterPolicy::TenantFair);
    // The noisy tenant's quota is 4 worst-case requests fleet-wide;
    // submit 10 worst-case requests so the tail is quota-blocked in
    // the ingress backlog.
    let handles: Vec<RequestHandle> = (0..10)
        .map(|i| {
            fleet.submit(SubmitRequest::new(32, 48)
                .with_id(9_000 + i)
                .with_tenant("noisy"))
        })
        .collect();
    let tail = *handles.last().unwrap();
    assert_eq!(fleet.poll(tail), Some(RequestStatus::Queued),
               "flood tail should be waiting at the front door");
    assert!(fleet.cancel(tail).unwrap(), "backlog cancel must land");
    assert_eq!(fleet.poll(tail),
               Some(RequestStatus::Finished(Outcome::Cancelled)));
    assert!(!fleet.cancel(tail).unwrap(),
            "second cancel of a terminal request must be a no-op");
    // drain: quota frees as the admitted flood completes, releasing
    // the rest of the backlog
    for k in 1..=1200 {
        fleet.step(k as f64 * 0.5).unwrap();
    }
    let report = fleet.report();
    assert_eq!(report.total_requests, 10);
    assert_eq!(report.cancelled, 1,
               "the backlog cancel must be a terminal outcome");
    assert_eq!(report.completed, 9, "everyone else runs to completion");
    assert_conserved(&report, 0, "cancel-from-backlog");
    for h in handles {
        assert!(matches!(fleet.poll(h),
                         Some(RequestStatus::Finished(_))),
                "request {} not terminal after drain", h.id);
    }
}

/// Drain-with-backlog: truncating the run while the tenant-fair
/// backlog still holds requests (and replicas still hold work) must
/// keep the books closed — stranded and never-offered arrivals are
/// terminal, in-flight work is pending, and submitted covers it all.
#[test]
fn conservation_holds_when_the_run_drains_with_a_backlog() {
    let mut fleet = tenant_storm_fleet(42, RouterPolicy::TenantFair);
    fleet.cfg.max_sim_secs = 6.0; // truncate mid-storm
    let reqs = tenant_storm_trace(42);
    let ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
    let report = fleet.run_requests(reqs).unwrap();
    assert!(report.dropped > 0,
            "scenario no longer strands arrivals at truncation");
    let pending = ids
        .iter()
        .filter(|&&id| {
            !matches!(fleet.poll(RequestHandle { id }),
                      Some(RequestStatus::Finished(_)))
        })
        .count() as u64;
    assert!(pending > 0,
            "scenario no longer truncates with work in flight");
    assert_conserved(&report, pending, "drain-with-backlog");
}

/// The O(1) poll index agrees with the exhaustive fleet scan on every
/// id of a full seeded run — including ids that migrated, crashed,
/// restored, and resumed (the chaos storm exercises every location
/// transition).
#[test]
fn poll_index_agrees_with_the_exhaustive_scan() {
    let mut fleet = chaos_storm_fleet(42, true);
    let reqs = chaos_storm_trace(42);
    let ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
    fleet.run_requests(reqs).unwrap();
    for id in ids {
        let h = RequestHandle { id };
        assert_eq!(fleet.poll(h), fleet.poll_scan(h),
                   "poll index diverged from the scan for id {id}");
    }
}

//! End-to-end integration over the real PJRT artifacts (requires
//! `make artifacts`). Uses rap-tiny for speed plus targeted rap-small
//! checks, and validates the full decode path against the score path.

use rap::corpus::{Corpus, Split};
use rap::mask::PruneMask;
use rap::model_meta::BlockId;
use rap::runtime::Runtime;
use rap::util::rng::Rng;

fn artifacts() -> std::path::PathBuf {
    // tests run from the workspace root
    rap::artifacts_dir()
}

fn have_artifacts() -> bool {
    artifacts().join("rap-tiny/manifest.json").exists()
}

#[test]
fn tiny_score_runs_and_gates_match_shapes() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let mut rt = Runtime::load(&artifacts(), "rap-tiny").unwrap();
    let meta = rt.meta().clone();
    let mut rng = Rng::new(1);
    let (b, t) = (4, 64);
    let tokens: Vec<i32> =
        (0..b * t).map(|_| rng.below(meta.vocab) as i32).collect();
    let full = PruneMask::full(&meta);
    let nll_dense = rt.mean_nll(b, t, &tokens, &full).unwrap();
    assert!(nll_dense.is_finite() && nll_dense > 0.0);
    // trained tiny model must beat uniform on its own chain? random
    // tokens here, so just sanity-bound it
    assert!(nll_dense < 2.0 * (meta.vocab as f64).ln());
}

#[test]
fn tiny_pruning_degrades_nll_monotonically_in_expectation() {
    if !have_artifacts() {
        return;
    }
    let mut rt = Runtime::load(&artifacts(), "rap-tiny").unwrap();
    let meta = rt.meta().clone();
    let mut rng = Rng::new(2);
    let (b, t) = (4, 64);
    let tokens: Vec<i32> =
        (0..b * t).map(|_| rng.below(meta.vocab) as i32).collect();
    let full = PruneMask::full(&meta);
    let dense = rt.mean_nll(b, t, &tokens, &full).unwrap();
    // drop everything → far worse than dense
    let mut empty = full.clone();
    for blk in meta.all_blocks() {
        empty.drop_block(blk);
    }
    let destroyed = rt.mean_nll(b, t, &tokens, &empty).unwrap();
    assert!(destroyed > dense + 0.1,
            "destroyed {destroyed} vs dense {dense}");
}

#[test]
fn tiny_probe_outputs_sane() {
    if !have_artifacts() {
        return;
    }
    let mut rt = Runtime::load(&artifacts(), "rap-tiny").unwrap();
    let meta = rt.meta().clone();
    let Ok((_, pb, pt)) = rt.probe_entry() else {
        eprintln!("skipping: no probe entry in this artifact build");
        return;
    };
    let mut rng = Rng::new(3);
    let tokens: Vec<i32> =
        (0..pb * pt).map(|_| rng.below(meta.vocab) as i32).collect();
    let full = PruneMask::full(&meta);
    let probe = rt.probe(&tokens, &full).unwrap();
    assert_eq!(probe.attn_cos.len(), meta.n_layers);
    assert_eq!(probe.ffn_cos.len(), meta.n_layers);
    assert_eq!(probe.head_norm.len(), meta.n_layers * meta.n_heads);
    assert_eq!(probe.chan_norm.len(), meta.n_layers * meta.d_ff);
    for &c in probe.attn_cos.iter().chain(&probe.ffn_cos) {
        assert!(c > -1.01 && c < 1.01, "cos out of range: {c}");
    }
    for &n in probe.head_norm.iter().chain(&probe.chan_norm) {
        assert!(n >= 0.0 && n.is_finite());
    }
}

#[test]
fn tiny_prefill_decode_matches_score_path() {
    // The strongest cross-entry invariant: greedy decode continuations
    // produced by prefill+decode must assign the same NLL to a sequence
    // as the score path does (same weights, same math, different HLO).
    if !have_artifacts() {
        return;
    }
    let mut rt = Runtime::load(&artifacts(), "rap-tiny").unwrap();
    let meta = rt.meta().clone();
    let full = PruneMask::full(&meta);
    let mut rng = Rng::new(4);
    let prompt_len = 16usize;
    let tokens: Vec<i32> = (0..prompt_len)
        .map(|_| rng.below(meta.vocab) as i32)
        .collect();
    // prefill then greedy-decode 4 tokens
    let (logits, mut k, mut v) = rt
        .prefill(prompt_len, &tokens, &full)
        .unwrap();
    let mut seq = tokens.clone();
    let mut next = argmax(&logits) as i32;
    for step in 0..4 {
        seq.push(next);
        let pos = [(prompt_len + step) as i32];
        let lg = rt
            .decode(1, &[next], &pos, &mut k, &mut v, &full)
            .unwrap();
        next = argmax(&lg) as i32;
    }
    // score the full 20-token sequence; NLL of the decoded tokens under
    // the score path must be small at the argmax positions (each decoded
    // token was the argmax → its logprob is the max → NLL below ln(V)).
    let t = seq.len();
    let entry_t = 64usize;
    let mut padded = vec![0i32; entry_t * 4];
    padded[..t].copy_from_slice(&seq);
    let mut mask_v = vec![0.0f32; entry_t * 4];
    for (i, m) in mask_v.iter_mut().enumerate().take(t).skip(prompt_len) {
        let _ = i;
        *m = 1.0;
    }
    let (nll, cnt) = rt.score(4, entry_t, &padded, &mask_v, &full).unwrap();
    let mean = nll[0] as f64 / cnt[0] as f64;
    assert!(mean < (meta.vocab as f64).ln(),
            "greedy tokens should be likely: mean NLL {mean}");
}

#[test]
fn small_model_beats_uniform_on_its_corpus() {
    if !have_artifacts()
        || !artifacts().join("rap-small/manifest.json").exists()
    {
        return;
    }
    let mut rt = Runtime::load(&artifacts(), "rap-small").unwrap();
    let corpus = Corpus::load(&artifacts().join("corpus")).unwrap();
    let meta = rt.meta().clone();
    let full = PruneMask::full(&meta);
    let tokens = corpus.batches(Split::Wiki, 4, 128, 1, 0).unwrap()
        .remove(0);
    let nll = rt.mean_nll(4, 128, &tokens, &full).unwrap();
    let uniform = (meta.vocab as f64).ln();
    assert!(nll < uniform - 0.5,
            "model did not learn: nll {nll} vs uniform {uniform}");
}

#[test]
fn small_mha_and_ffn_pruning_both_hurt() {
    if !have_artifacts()
        || !artifacts().join("rap-small/manifest.json").exists()
    {
        return;
    }
    let mut rt = Runtime::load(&artifacts(), "rap-small").unwrap();
    let corpus = Corpus::load(&artifacts().join("corpus")).unwrap();
    let meta = rt.meta().clone();
    let full = PruneMask::full(&meta);
    let tokens = corpus.batches(Split::Wiki, 4, 128, 1, 0).unwrap()
        .remove(0);
    let dense = rt.mean_nll(4, 128, &tokens, &full).unwrap();
    let mut no_mha = full.clone();
    let mut no_ffn = full.clone();
    for l in 0..meta.n_layers {
        no_mha.drop_block(BlockId::Mha(l));
        no_ffn.drop_block(BlockId::Ffn(l));
    }
    let nll_no_mha = rt.mean_nll(4, 128, &tokens, &no_mha).unwrap();
    let nll_no_ffn = rt.mean_nll(4, 128, &tokens, &no_ffn).unwrap();
    // both pathways are load-bearing (corpus has bigram + induction
    // structure, see python/compile/corpus.py)
    assert!(nll_no_mha > dense + 0.05, "{nll_no_mha} vs {dense}");
    assert!(nll_no_ffn > dense + 0.3, "{nll_no_ffn} vs {dense}");
}

fn argmax(xs: &[f32]) -> usize {
    let mut b = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[b] {
            b = i;
        }
    }
    b
}

//! Flight-recorder acceptance (ISSUE 7): the observer-effect guard —
//! attaching the full telemetry stack (event bus, trace export, metrics
//! sampler) to a seeded run must not move a single byte of the report
//! JSON — plus trace-export determinism, structural trace validation,
//! and the chaos-storm life-story reconstruction: a crash-disturbed
//! request whose audit shows submit → checkpoint → crash → restore →
//! resume → done, with the replacement spawn attributed to the
//! capacity-loss signal.

use std::collections::{BTreeMap, BTreeSet};

use rap::coordinator::fleet::{chaos_storm_fleet, chaos_storm_trace,
                              elastic_demo_fleet, elastic_demo_trace,
                              tenant_storm_fleet, tenant_storm_trace,
                              Fleet};
use rap::coordinator::router::RouterPolicy;
use rap::telemetry::trace;
use rap::util::json::Json;

fn with_telemetry(mut fleet: Fleet) -> Fleet {
    fleet.enable_telemetry();
    fleet.enable_metrics_sampling(1.0);
    fleet
}

/// Run the chaos storm with telemetry attached and return (report JSON,
/// trace document).
fn chaos_run(seed: u64) -> (String, Json) {
    let mut fleet = with_telemetry(chaos_storm_fleet(seed, true));
    let report = fleet.run_requests(chaos_storm_trace(seed)).unwrap();
    let trace = fleet.trace_json().expect("telemetry was enabled");
    (report.to_json().pretty(), trace)
}

/// The tentpole contract: seeded report bytes are identical with the
/// recorder, trace export, and metrics sampler attached vs detached, on
/// every fleet scenario family (PR-3 elastic, PR-5 tenant storm, PR-6
/// chaos storm).
#[test]
fn telemetry_does_not_perturb_seeded_reports() {
    // PR-3 elastic demo (engine-level Request trace)
    let plain = elastic_demo_fleet(7, true)
        .run_trace(elastic_demo_trace(7)).unwrap();
    let observed = with_telemetry(elastic_demo_fleet(7, true))
        .run_trace(elastic_demo_trace(7)).unwrap();
    assert_eq!(plain.to_json().pretty(), observed.to_json().pretty(),
               "telemetry perturbed the elastic-demo report");

    // PR-5 tenant storm (SLO ingress + fair routing)
    let plain = tenant_storm_fleet(42, RouterPolicy::TenantFair)
        .run_requests(tenant_storm_trace(42)).unwrap();
    let observed =
        with_telemetry(tenant_storm_fleet(42, RouterPolicy::TenantFair))
            .run_requests(tenant_storm_trace(42)).unwrap();
    assert_eq!(plain.to_json().pretty(), observed.to_json().pretty(),
               "telemetry perturbed the tenant-storm report");

    // PR-6 chaos storm (faults, checkpoints, capacity-loss autoscale)
    let plain = chaos_storm_fleet(42, true)
        .run_requests(chaos_storm_trace(42)).unwrap();
    let (observed, _) = chaos_run(42);
    assert_eq!(plain.to_json().pretty(), observed,
               "telemetry perturbed the chaos-storm report");
}

/// Same seed, two runs → byte-identical trace files. Sim time only —
/// no wall-clock leaks into the export.
#[test]
fn seeded_trace_export_is_byte_deterministic() {
    let (_, a) = chaos_run(42);
    let (_, b) = chaos_run(42);
    assert_eq!(a.pretty(), b.pretty(),
               "same seed produced different trace bytes");
    let (_, c) = chaos_run(7);
    assert_ne!(a.pretty(), c.pretty(),
               "different seeds produced identical traces — \
                the export is not actually recording the run");
}

/// The chaos-storm export is structurally a Chrome trace: monotone
/// timestamps, balanced spans, and a span track for every audited
/// request — and the crash tripped the flight recorder.
#[test]
fn chaos_storm_trace_is_a_valid_chrome_trace() {
    let (_, doc) = chaos_run(42);
    let stats = trace::validate(&doc).unwrap();
    assert!(stats.requests > 0, "no request tracks in the trace");
    assert!(stats.spans > 0 && stats.instants > 0);
    assert!(stats.audit_events > stats.requests,
            "audit stream thinner than one event per request");
    let dumps = doc.get("flightRecorder").unwrap().arr().unwrap();
    assert!(!dumps.is_empty(),
            "the replica crash did not trip a flight-recorder dump");
    assert!(dumps.iter().any(|d| {
        d.get("reason").unwrap().str().unwrap().contains("crash")
    }), "no crash-attributed dump: {dumps:?}");
}

/// Per-request event-kind sets from the decision audit stream.
fn kinds_by_request(doc: &Json) -> BTreeMap<u64, BTreeSet<String>> {
    let mut by_req: BTreeMap<u64, BTreeSet<String>> = BTreeMap::new();
    for e in doc.get("events").unwrap().arr().unwrap() {
        if let Ok(id) = e.get("request").and_then(|j| j.num()) {
            by_req.entry(id as u64).or_default()
                .insert(e.get("event").unwrap().str().unwrap()
                         .to_string());
        }
    }
    by_req
}

/// The acceptance lifecycle: at seed 42 the checkpointed chaos fleet
/// restores crash-interrupted work, so some request's audit must show
/// the full submit → checkpoint → crash → restore → resume → done
/// chain, and the autoscaler's replacement spawn must be attributed to
/// the capacity-loss signal it actually fired on.
#[test]
fn chaos_trace_reconstructs_a_crash_disturbed_lifecycle() {
    let (_, doc) = chaos_run(42);
    let by_req = kinds_by_request(&doc);
    // terminal events are named by their outcome ("done"), so the full
    // chain is directly readable from the per-request kind sets
    let chain = ["submit", "checkpoint", "crash", "restore", "resume",
                 "done"];
    let audit = doc.get("events").unwrap().arr().unwrap();
    let survivor = by_req.iter().find(|(_, kinds)| {
        chain.iter().all(|k| kinds.contains(*k))
    });
    let (&id, _) = survivor.unwrap_or_else(|| {
        panic!("no request survived the full crash-recovery chain \
                {chain:?}; per-request kinds: {by_req:?}")
    });

    // `rap trace summarize` tells that story, in causal order
    let story = trace::summarize(&doc, Some(id)).unwrap();
    let order: Vec<usize> = ["submit", "checkpoint", "crash", "restore",
                             "resume", "outcome=done"]
        .iter()
        .map(|s| story.find(s).unwrap_or_else(|| {
            panic!("step {s:?} missing from life story:\n{story}")
        }))
        .collect();
    assert!(order.windows(2).all(|w| w[0] < w[1]),
            "life story out of causal order:\n{story}");

    // the replacement capacity is audited with its triggering signal
    let spawn = audit.iter().find(|e| {
        e.get("event").and_then(|k| k.str())
            .is_ok_and(|k| k == "autoscale-spawn")
    }).expect("no autoscale-spawn in the chaos audit");
    let args = spawn.get("args").unwrap();
    assert_eq!(args.get("trigger").unwrap().str().unwrap(),
               "capacity-loss");
    assert!(args.get("signals").unwrap().get("capacity_losses")
                .unwrap().num().unwrap() >= 1.0,
            "spawn attributed to capacity loss but the snapshot \
             recorded none: {spawn:?}");
}

/// The metrics registry is load-bearing (the autoscaler reads it), so
/// it is populated even without telemetry; the exposition must carry
/// the counter families CI greps for.
#[test]
fn prometheus_exposition_carries_core_families() {
    let mut fleet = with_telemetry(chaos_storm_fleet(42, true));
    fleet.run_requests(chaos_storm_trace(42)).unwrap();
    fleet.publish_metrics();
    let text = fleet.registry.prometheus();
    for family in ["rap_requests_completed_total", "rap_oom_events_total",
                   "rap_ttft_seconds", "rap_replicas_serving",
                   "rap_checkpoints_total"] {
        assert!(text.contains(family),
                "family {family} missing from exposition:\n{text}");
    }
    assert!(fleet.registry.samples() > 0,
            "metrics sampler produced no timeline samples");
}

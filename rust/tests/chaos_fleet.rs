//! Chaos-recovery harness (ISSUE 6): the chaos-storm acceptance
//! scenario — on the same seeded fault plan (link degrade, replica
//! crash mid-flood, partition, spot reclaim), the checkpoint-enabled
//! fleet must lose strictly fewer sequences AND hold a strictly better
//! latency-tenant deadline hit-rate than the checkpoint-free fleet,
//! with every arrival reaching exactly one terminal state — plus the
//! determinism contracts for the chaos report JSON and the seeded
//! fault-plan generator.

use rap::coordinator::fleet::{chaos_storm_fleet, chaos_storm_trace};
use rap::coordinator::metrics::{FleetReport, FleetTenantReport};
use rap::runtime::FaultPlan;

fn tenant<'a>(r: &'a FleetReport, name: &str) -> &'a FleetTenantReport {
    r.tenants
        .iter()
        .find(|t| t.tenant == name)
        .unwrap_or_else(|| panic!("tenant '{name}' missing: {r:?}"))
}

/// Arrivals that never reached a terminal outcome by drain time.
fn nonterminal(r: &FleetReport) -> u64 {
    r.total_requests.saturating_sub(
        r.completed as u64 + r.rejected + r.cancelled + r.deadline_missed
            + r.dropped)
}

/// The ISSUE-6 acceptance inequality on the CI smoke seed: same trace,
/// same fault plan, the only difference is 1 s periodic KV
/// checkpointing — and that difference must buy strictly fewer lost
/// sequences AND a strictly better latency-tenant deadline hit-rate,
/// with zero requests stuck non-terminal in either run. Reproducible
/// via `rap experiment fleet --chaos --seed 42`.
#[test]
fn checkpointed_fleet_beats_checkpoint_free_on_the_chaos_storm() {
    let seed = 42;
    let reqs = chaos_storm_trace(seed);
    let n = reqs.len() as u64;
    let mut plain = chaos_storm_fleet(seed, false);
    let pr = plain.run_requests(reqs.clone()).unwrap();
    let mut ckpt = chaos_storm_fleet(seed, true);
    let cr = ckpt.run_requests(reqs).unwrap();

    // the fault plan really fired, identically, in both runs
    for r in [&pr, &cr] {
        assert_eq!(r.chaos.failures_injected, 4,
                   "fault plan did not fully fire: {r:?}");
        assert!(r.chaos.crashes >= 1, "no crash landed: {r:?}");
        assert_eq!(r.chaos.reclaims, 1, "no reclaim landed: {r:?}");
    }
    // the baseline takes the crash with no safety net
    assert_eq!(pr.chaos.checkpoints_taken, 0);
    assert_eq!(pr.chaos.seq_restored, 0);
    assert!(pr.chaos.seq_lost > 0,
            "the crash cost the baseline nothing — toothless: {pr:?}");
    // the checkpointed fleet actually checkpointed and restored
    assert!(cr.chaos.checkpoints_taken > 0, "no checkpoints: {cr:?}");
    assert!(cr.chaos.checkpoint_bytes > 0, "free checkpoints: {cr:?}");
    assert!(cr.chaos.seq_restored > 0, "nothing restored: {cr:?}");

    // the acceptance inequality, strict on both axes
    assert!(cr.chaos.seq_lost < pr.chaos.seq_lost,
            "checkpointing did not strictly cut sequences lost: {} vs {}",
            cr.chaos.seq_lost, pr.chaos.seq_lost);
    let p_lat = tenant(&pr, "latency");
    let c_lat = tenant(&cr, "latency");
    assert!(c_lat.deadline_hit_rate() > p_lat.deadline_hit_rate(),
            "checkpointing did not strictly lift the latency tenant's \
             hit-rate: {:.3} vs {:.3}",
            c_lat.deadline_hit_rate(), p_lat.deadline_hit_rate());

    // conservation: every arrival reached exactly one terminal state —
    // nothing lost forever, nothing double-completed
    for r in [&pr, &cr] {
        assert_eq!(nonterminal(r), 0,
                   "requests stuck non-terminal at drain: {r:?}");
        let accounted: usize = r
            .tenants
            .iter()
            .map(|t| {
                t.counts.finished + t.counts.deadline_missed
                    + t.counts.cancelled + t.counts.rejected
            })
            .sum();
        assert_eq!(accounted as u64 + r.dropped, n,
                   "arrivals unaccounted for: {r:?}");
    }
}

/// Same seed twice → byte-identical report JSON: the determinism
/// contract extends through failure injection and recovery.
#[test]
fn chaos_storm_is_deterministic_per_seed() {
    let run = |seed: u64| {
        let mut fleet = chaos_storm_fleet(seed, true);
        let report = fleet.run_requests(chaos_storm_trace(seed)).unwrap();
        report.to_json().pretty()
    };
    let a = run(17);
    let b = run(17);
    assert_eq!(a, b, "same seed must reproduce the report byte for byte");
    let c = run(18);
    assert_ne!(a, c, "different seeds should differ");
}

/// `FaultPlan::seeded` is a pure function of its inputs: same seed →
/// the same schedule, different seed → a different one, and every
/// event lands inside the horizon with a valid replica index.
#[test]
fn seeded_fault_plans_are_deterministic_and_well_formed() {
    let a = FaultPlan::seeded(5, 40.0, 3);
    let b = FaultPlan::seeded(5, 40.0, 3);
    assert_eq!(a.events, b.events, "same seed must reproduce the plan");
    let c = FaultPlan::seeded(6, 40.0, 3);
    assert_ne!(a.events, c.events, "different seeds should differ");
    assert!(!a.events.is_empty());
    for e in &a.events {
        let t = e.start();
        assert!((0.0..=40.0).contains(&t), "event outside horizon: {e:?}");
    }
    // degenerate inputs yield an empty, harmless plan
    assert!(FaultPlan::seeded(5, 0.0, 3).events.is_empty());
    assert!(FaultPlan::seeded(5, 40.0, 0).events.is_empty());
}

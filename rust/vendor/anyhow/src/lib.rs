//! In-tree, API-compatible subset of the `anyhow` crate.
//!
//! The offline build image has no crates.io access, so the error type the
//! whole crate leans on lives here as a path dependency. Only the surface
//! the repo actually uses is implemented: `Result`, `Error`, `anyhow!`,
//! `bail!`, `ensure!`, and the `Context` extension trait for `Result` and
//! `Option`. Errors carry a flattened message chain (context prefixes are
//! folded into one string) rather than `anyhow`'s full cause chain — every
//! call site here only ever formats the error, so nothing is lost.

use std::fmt;

/// A flattened error: the message already includes any context prefixes.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> Result<()>` prints the Debug form; show the
        // human-readable message like anyhow does.
        f.write_str(&self.msg)
    }
}

// Like anyhow: any std error converts into `Error`. `Error` itself does
// NOT implement `std::error::Error`, which is what keeps this blanket
// impl coherent next to the reflexive `From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-prefixing extension for `Result` and `Option` (subset of
/// `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| {
            let e: Error = e.into();
            Error { msg: format!("{ctx}: {e}") }
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let e: Error = e.into();
            Error { msg: format!("{}: {e}", f()) }
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_num(s: &str) -> Result<i32> {
        Ok(s.parse::<i32>()?)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_num("42").unwrap(), 42);
        assert!(parse_num("x").is_err());
    }

    #[test]
    fn context_prefixes_message() {
        let e = parse_num("x").context("reading config").unwrap_err();
        assert!(e.to_string().starts_with("reading config: "), "{e}");
        let o: Option<i32> = None;
        let e = o.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "slot 3");
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let x = 7;
        let e = anyhow!("value {x} and {}", 8);
        assert_eq!(e.to_string(), "value 7 and 8");
        fn f() -> Result<()> {
            bail!("boom {}", 1)
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 1");
        fn g(ok: bool) -> Result<i32> {
            ensure!(ok, "not ok");
            Ok(5)
        }
        assert_eq!(g(true).unwrap(), 5);
        assert!(g(false).is_err());
    }
}

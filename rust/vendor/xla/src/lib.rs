//! Stub of the PJRT/XLA binding surface used by `rap::runtime::pjrt`.
//!
//! The offline build image does not ship the XLA runtime, so this crate
//! provides the exact types and signatures the PJRT backend compiles
//! against; every entry point that would touch a device returns a clear
//! runtime error instead. The serving stack, fleet coordinator, and the
//! whole test suite run on the `rap::runtime::sim` backend and never hit
//! these paths.
//!
//! To execute real AOT artifacts, point the `xla` dependency in
//! `rust/Cargo.toml` at an actual PJRT binding with this API instead of
//! this stub — no source change in `rap` is needed.

use std::fmt;
use std::path::Path;

#[derive(Clone)]
pub struct Error {
    msg: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error {
        msg: format!(
            "{what}: the XLA/PJRT runtime is not available in this build \
             (in-tree stub `rust/vendor/xla`); use the sim backend or link \
             real PJRT bindings"
        ),
    }
}

/// Element types uploadable as device buffers.
pub trait ArrayElement: Copy {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}
impl ArrayElement for i32 {}
impl ArrayElement for i64 {}
impl ArrayElement for u8 {}

pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable("Literal::to_vec"))
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_clear_errors() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("stub"), "{e}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}

//! Benchmark harness (criterion-free: in-tree mini harness, see
//! `rap::util::bench`). One section per paper-relevant hot path:
//!
//!   runtime  — score / probe / prefill / decode entry latency (the L2+L1
//!              compute the paper's Table 1 and Fig 11 depend on)
//!   serving  — KV gather/scatter (the L3 hot loop)
//!   control  — warm policy decision, DQN forward (Fig 11)
//!   substrate— memory model, mask ops, JSON parse, PRNG
//!
//! Run with: cargo bench    (results land in bench_output.txt via make)

use rap::corpus::{Corpus, Split};
use rap::mask::PruneMask;
use rap::memory::{MemoryModel, Workload};
use rap::runtime::Runtime;
use rap::server::kv::KvManager;
use rap::util::bench::{bench, black_box};
use rap::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let root = rap::artifacts_dir();
    let have = root.join("rap-small/manifest.json").exists();
    println!("== rap paper benches ==");

    // ---------------- substrate (always available) ----------------
    {
        let meta =
            rap::model_meta::ModelMeta::synthetic("b", 12, 256, 8, 8,
                                                  1024, 512, 256);
        let mem = MemoryModel::new(&meta);
        let mask = PruneMask::full(&meta);
        let w = Workload::new(16, 256);
        println!("{}", bench("memory_model/peak_bytes", 0.3, 100_000,
                             || {
            black_box(mem.peak_bytes(black_box(&mask), w));
        }).report());
        println!("{}", bench("mask/key_hash", 0.3, 100_000, || {
            black_box(black_box(&mask).key());
        }).report());
        let mut rng = Rng::new(1);
        println!("{}", bench("rng/normal", 0.2, 1_000_000, || {
            black_box(rng.normal());
        }).report());
        let json_src = std::fs::read_to_string(
            root.join("rap-small/manifest.json")).unwrap_or_else(
            |_| "{\"a\": [1,2,3]}".into());
        println!("{}", bench("json/parse_manifest", 0.3, 10_000, || {
            black_box(rap::util::json::Json::parse(&json_src).unwrap());
        }).report());
    }

    if !have {
        println!("(artifacts missing — runtime benches skipped)");
        return Ok(());
    }

    // ---------------- runtime entries ----------------
    let mut rt = Runtime::load(&root, "rap-small")?;
    let corpus = Corpus::load(&root.join("corpus"))?;
    let meta = rt.meta().clone();
    let mask = PruneMask::full(&meta);

    let toks_b1 = corpus.batches(Split::Wiki, 1, 128, 1, 0)?.remove(0);
    let toks_b4 = corpus.batches(Split::Wiki, 4, 128, 1, 0)?.remove(0);
    let toks_b4_64 = corpus.batches(Split::Wiki, 4, 64, 1, 0)?.remove(0);
    let toks_b8 = corpus.batches(Split::Wiki, 8, 128, 1, 0)?.remove(0);
    rt.warmup(&["score_b1_t128", "score_b4_t128", "score_b4_t64",
                "score_b8_t128", "prefill_t64", "decode_b1",
                "decode_b8"])?;

    for (name, b, t, toks) in [("score_b1_t128", 1usize, 128usize,
                                &toks_b1),
                               ("score_b4_t64", 4, 64, &toks_b4_64),
                               ("score_b4_t128", 4, 128, &toks_b4),
                               ("score_b8_t128", 8, 128, &toks_b8)] {
        println!("{}", bench(&format!("runtime/{name}"), 2.0, 60, || {
            black_box(rt.mean_nll(b, t, toks, &mask).unwrap());
        }).report());
    }

    let prompt: Vec<i32> =
        corpus.wiki[..64].iter().map(|&t| t as i32).collect();
    println!("{}", bench("runtime/prefill_t64", 2.0, 60, || {
        black_box(rt.prefill(64, &prompt, &mask).unwrap());
    }).report());

    for b in [1usize, 8] {
        let mut k = vec![0.0f32; rt.cache_elems(b)];
        let mut v = vec![0.0f32; rt.cache_elems(b)];
        let toks = vec![1i32; b];
        let pos = vec![64i32; b];
        println!("{}", bench(&format!("runtime/decode_b{b}"), 2.0, 60,
                             || {
            black_box(rt.decode(b, &toks, &pos, &mut k, &mut v, &mask)
                .unwrap());
        }).report());
    }

    // ---------------- serving hot loop ----------------
    {
        let mut kv = KvManager::new(&meta);
        let n = kv.seq_elems();
        for id in 0..8u64 {
            kv.insert(id, vec![0.1; n], vec![0.2; n], 64, &mask)?;
        }
        let ids: Vec<u64> = (0..8).collect();
        println!("{}", bench("serving/kv_gather_b8", 1.0, 2_000, || {
            black_box(kv.gather(&ids).unwrap());
        }).report());
        let (k, v) = kv.gather(&ids)?;
        println!("{}", bench("serving/kv_scatter_b8", 1.0, 2_000, || {
            kv.scatter(&ids, &k, &v, &mask).unwrap();
            for id in 0..8u64 {
                if kv.seq_len(id) == Some(meta.max_seq) {
                    kv.remove(id);
                    kv.insert(id, k[..n].to_vec(), v[..n].to_vec(), 64,
                              &mask).unwrap();
                }
            }
        }).report());
    }

    // ---------------- controller ----------------
    {
        use rap::agent::dqn::{DqnAgent, DqnConfig};
        use rap::agent::env::{EnvConfig, PruneEnv};
        use rap::gsi::CalibratedEvaluator;
        let mut ev = CalibratedEvaluator::new(rt, &corpus, 1, 128)?;
        let mut rng = Rng::new(2);
        let mut env = PruneEnv::new(&mut ev, EnvConfig::default());
        let agent = DqnAgent::new(env.state_dim(), env.n_actions(),
                                  DqnConfig::default(), &mut rng);
        let state = env.reset(Workload::new(8, 256), 0.8)?;
        println!("{}", bench("control/dqn_forward", 0.5, 100_000, || {
            black_box(agent.q.forward(black_box(&state)));
        }).report());
        // warm the GSI memo, then time a full warm policy decision
        let _ = rap::agent::online_prune(&agent, &mut env,
                                         Workload::new(8, 256), 0.8)?;
        println!("{}", bench("control/online_prune_warm", 1.0, 200, || {
            black_box(rap::agent::online_prune(
                &agent, &mut env, Workload::new(8, 256), 0.8).unwrap());
        }).report());
    }

    println!("== done ==");
    Ok(())
}

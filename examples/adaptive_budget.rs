//! Budget sweep: watch RAP adapt the mask as the memory budget tightens
//! from 100% down to 50%, reporting which blocks go, the realized
//! memory, and perplexity — the "elastic pruning" behaviour of the paper
//! in one table.
//!
//! Run with:  cargo run --release --example adaptive_budget

use anyhow::Result;
use rap::corpus::Split;
use rap::evalharness::perplexity;
use rap::experiments::common::setup;
use rap::gsi::{CalibratedEvaluator, GsiEngine};
use rap::mask::PruneMask;
use rap::memory::{mib, Workload};

fn main() -> Result<()> {
    let s = setup("rap-small")?;
    let rt = s.rt;
    let corpus = s.corpus;
    let mem = s.mem;
    let meta = rt.meta().clone();
    let w = Workload::new(16, meta.max_seq);
    let dense_peak = mem.dense_peak_bytes(w);
    println!("workload: batch {} × seq {}  (dense peak {:.1} MiB)",
             w.batch, w.seqlen, mib(dense_peak));

    let mut ev = CalibratedEvaluator::new(rt, &corpus, 4, 128)?;
    let mut gsi = GsiEngine::new(&mut ev);

    println!("\n{:>7} {:>10} {:>8} {:>8} {:>9}   dropped blocks",
             "budget", "peak MiB", "weight%", "kv-heads", "PPL");
    let mut masks = Vec::new();
    for pct in [100usize, 90, 80, 70, 60, 50] {
        let budget = dense_peak * pct / 100;
        let full = PruneMask::full(&meta);
        let res = gsi.greedy(&full, |m| {
            mem.peak_bytes(m, w) <= budget
        })?;
        let mut mask = full;
        for b in &res.order {
            mask.drop_block(*b);
        }
        masks.push((pct, mask));
    }
    // evaluate after GSI so the engine's runtime borrow is released
    let mut rt = ev.rt;
    for (pct, mask) in masks {
        let ppl = perplexity(&mut rt, &corpus, Split::Wiki, &mask, 4, 128,
                             3)?;
        let kv_heads: usize =
            (0..meta.n_layers).map(|l| mask.active_kv_groups(l)).sum();
        let blocks: Vec<String> = mask
            .dropped_blocks()
            .iter()
            .map(|b| b.to_string())
            .collect();
        println!("{:>6}% {:>10.1} {:>7.1}% {:>8} {:>9.2}   {}", pct,
                 mib(mem.peak_bytes(&mask, w)),
                 mask.param_fraction(&meta) * 100.0, kv_heads, ppl,
                 blocks.join(","));
    }
    println!("\nNote how MHA blocks (which free KV cache) and FFN blocks \
              (which free parameters) are traded off differently as the \
              budget tightens — the asymmetry Table 4 quantifies.");
    Ok(())
}

//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): replay a bursty, diurnal
//! request trace through the full serving stack — continuous batcher,
//! KV manager, memory monitor with co-running interference, and the RAP
//! controller — with every forward pass executing the AOT-compiled HLO
//! through PJRT (or the deterministic sim backend when no artifacts are
//! on disk). Reports latency/throughput/OOM for a static-dense
//! deployment vs RAP.
//!
//! Run with:  cargo run --release --example serve_trace -- [secs] [seed]

use anyhow::Result;
use rap::experiments::common::setup;
use rap::mask::PruneMask;
use rap::server::controller::{Controller, Policy};
use rap::server::engine::{Engine, EngineConfig};
use rap::server::memmon::{MemMonConfig, MemoryMonitor};
use rap::workload::{TraceConfig, TraceGenerator};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let secs: f64 = args.get(1).map(|s| s.parse().unwrap()).unwrap_or(120.0);
    let seed: u64 = args.get(2).map(|s| s.parse().unwrap()).unwrap_or(7);

    for policy_name in ["static-dense", "rap"] {
        let s = setup("rap-small")?;
        let rt = s.rt;
        let corpus = s.corpus;
        let meta = rt.meta().clone();
        let mem = s.mem;
        // capacity: 1.35× the dense parameter bytes — headroom for the
        // dense model + a moderate KV set, but interference (~30%-of-
        // capacity chunks) forces decisions
        let capacity = (mem.param_bytes(&PruneMask::full(&meta))
            as f64 * 1.35) as usize;
        let monitor = MemoryMonitor::new(MemMonConfig {
            app_rate: 0.1,
            mean_hold_secs: 25.0,
            size_mu: (capacity as f64 * 0.30).ln(),
            ..MemMonConfig::for_capacity(capacity)
        }, seed);
        let calib = corpus
            .batches(rap::corpus::Split::Alpaca, 1, 128, 1, 0)?
            .remove(0);
        let policy = match policy_name {
            "static-dense" => Policy::Static(PruneMask::full(&meta)),
            _ => Policy::GsiGreedy,
        };
        let controller = Controller::new(policy, mem.clone(), calib, 128);
        let mut engine = Engine::new(rt, monitor, controller,
                                     EngineConfig::default());
        let mut gen = TraceGenerator::new(
            TraceConfig { base_rate: 1.5, ..TraceConfig::default() },
            seed + 100);
        let reqs = gen.generate(0.0, secs);
        println!("\n### policy = {policy_name}: {} requests over {secs}s \
                  simulated", reqs.len());
        let t0 = std::time::Instant::now();
        let report = engine.run_trace(reqs)?;
        report.print(policy_name);
        println!("   (real wall time {:.1}s)", t0.elapsed().as_secs_f64());
    }
    println!("\nExpected shape: RAP completes ≥ the static deployment's \
              requests with ~0 OOM events by shrinking the model when \
              interference spikes.");
    Ok(())
}

//! FLEET DRIVER: replay one seeded, bursty trace across N heterogeneous
//! serving replicas — different capacities, co-tenant interference
//! profiles, and device speeds — under each routing policy in turn, and
//! compare what the router's memory-awareness buys: round-robin and
//! least-outstanding dispatch blindly, kv-headroom reads Sys_avail(t),
//! and rap-aware additionally prices each request's KV cost under every
//! replica's currently-deployed pruning mask.
//!
//! Runs entirely on the deterministic sim runtime backend — no AOT
//! artifacts needed.
//!
//! Run with:  cargo run --release --example serve_fleet -- \
//!                [replicas] [secs] [seed]

use anyhow::Result;
use rap::coordinator::fleet::{default_fleet_trace, default_sim_fleet,
                              default_sim_fleet_with, AutoscaleConfig,
                              FleetConfig};
use rap::coordinator::router::RouterPolicy;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let replicas: usize =
        args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let secs: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(120.0);
    let seed: u64 = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(7);

    let trace = default_fleet_trace(seed, secs);
    println!("fleet of {replicas} replicas · {} requests over {secs:.0}s \
              · seed {seed}", trace.len());

    for policy in RouterPolicy::ALL {
        let mut fleet = default_sim_fleet(replicas, seed, policy);
        fleet.cfg.max_sim_secs = secs + 3600.0; // arrivals + drain window
        let report = fleet.run_trace(trace.clone())?;
        println!();
        report.print();
    }

    // The same trace once more, elastically: the fleet may spawn up to
    // 2× the replicas under load, retire them when it drains, and
    // migrate in-flight sequences off pressured replicas instead of
    // evicting them.
    let cfg = FleetConfig {
        migrate: true,
        autoscale: Some(AutoscaleConfig {
            max_replicas: (replicas * 2).max(2),
            ..AutoscaleConfig::default()
        }),
        max_sim_secs: secs + 3600.0,
        ..FleetConfig::default()
    };
    let mut fleet = default_sim_fleet_with(replicas, seed,
                                           RouterPolicy::RapAware, cfg);
    let report = fleet.run_trace(trace.clone())?;
    println!("\n— elastic (rap-aware router + autoscale + migration) —");
    report.print();

    println!("\nExpected shape: the memory-aware routers end with fewer \
              OOM events and fewer rejected requests than round-robin; \
              rap-aware should also hold the best p99 latency because it \
              avoids replicas serving with heavily pruned masks. The \
              elastic run turns evictions into migrations and absorbs \
              bursts by spawning replicas.");
    Ok(())
}

//! Quickstart: load the model (AOT artifacts when present, the
//! deterministic sim backend otherwise), ask RAP for a mask that fits
//! an 80% memory budget, and compare dense vs pruned perplexity + a
//! short greedy generation.
//!
//! Run with:  cargo run --release --example quickstart

use anyhow::Result;
use rap::corpus::Split;
use rap::evalharness::perplexity;
use rap::experiments::common::setup;
use rap::gsi::{CalibratedEvaluator, GsiEngine};
use rap::mask::PruneMask;
use rap::memory::{mib, Workload};
use rap::pruning::{build_mask_eval, PruneContext, Scheme};

fn main() -> Result<()> {
    let s = setup("rap-small")?;
    let rt = s.rt;
    let corpus = s.corpus;
    let mem = s.mem;
    let meta = rt.meta().clone();
    println!("serving rap-small on the {} backend",
             if rt.is_sim() { "sim" } else { "pjrt" });

    // The budget: 80% of the dense peak at a KV-heavy workload.
    let w = Workload::new(16, meta.max_seq);
    let budget = mem.budget_bytes(w, 0.8);
    println!("dense peak {:.1} MiB → budget {:.1} MiB",
             mib(mem.dense_peak_bytes(w)), mib(budget));

    // Ask RAP (GSI-greedy flavour) for a mask.
    let mut ev = CalibratedEvaluator::new(rt, &corpus, 4, 128)?;
    let mut gsi = GsiEngine::new(&mut ev);
    let probe_placeholder = rap::runtime::ProbeStats {
        attn_cos: vec![0.0; meta.n_layers],
        ffn_cos: vec![0.0; meta.n_layers],
        head_norm: vec![0.0; meta.n_layers * meta.n_heads],
        chan_norm: vec![0.0; meta.n_layers * meta.d_ff],
    };
    let ctx = PruneContext { mem: &mem, probe: &probe_placeholder,
                             workload: w, budget_bytes: budget, seed: 1 };
    let mask = build_mask_eval(Scheme::RapGreedy, &ctx, &mut gsi)?;
    println!("RAP pruned blocks: {:?}",
             mask.dropped_blocks().iter().map(|b| b.to_string())
                 .collect::<Vec<_>>());
    println!("pruned peak {:.1} MiB ({:.1}% of weights removed)",
             mib(mem.peak_bytes(&mask, w)),
             (1.0 - mask.param_fraction(&meta)) * 100.0);

    let mut rt = ev.rt;
    let dense = PruneMask::full(&meta);
    let p_dense = perplexity(&mut rt, &corpus, Split::Wiki, &dense, 4,
                             128, 4)?;
    let p_rap = perplexity(&mut rt, &corpus, Split::Wiki, &mask, 4, 128,
                           4)?;
    println!("wikitext2-sim PPL: dense {p_dense:.2} → RAP {p_rap:.2}");

    // Short greedy generation through prefill + decode.
    let prompt: Vec<i32> = corpus.wiki[..16].iter().map(|&t| t as i32)
        .collect();
    let (logits, mut k, mut v) = rt.prefill(16, &prompt, &mask)?;
    let mut tok = argmax(&logits) as i32;
    let mut text = prompt.clone();
    for step in 0..24 {
        text.push(tok);
        let lg = rt.decode(1, &[tok], &[(16 + step) as i32], &mut k,
                           &mut v, &mask)?;
        tok = argmax(&lg) as i32;
    }
    println!("greedy continuation (token ids): {:?}", &text[16..]);
    println!("done.");
    Ok(())
}

fn argmax(xs: &[f32]) -> usize {
    let mut b = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[b] {
            b = i;
        }
    }
    b
}

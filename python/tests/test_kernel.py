"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

Hypothesis is not available in this image, so the shape/dtype sweep is an
explicit parameterized grid plus a seeded random-case fuzz loop — same
coverage intent: many shapes, gating patterns, GQA group sizes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.attention import decode_attention, gated_attention
from compile.kernels.gated_ffn import gated_ffn


def rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------- FFN --

FFN_GRID = [
    # (T, D, F, gate_keep_prob)
    (8, 16, 32, 1.0),
    (64, 32, 96, 0.7),
    (128, 64, 256, 0.5),
    (96, 48, 144, 0.0),     # fully pruned block
    (256, 128, 512, 0.9),
    (1, 16, 48, 0.5),       # single row
]


@pytest.mark.parametrize("t,d,f,keep", FFN_GRID)
def test_gated_ffn_matches_ref(t, d, f, keep):
    k = keys(t * 7 + d, 5)
    x = rand(k[0], t, d)
    wg, wu, wd = rand(k[1], d, f), rand(k[2], d, f), rand(k[3], f, d)
    gate = (jax.random.uniform(k[4], (f,)) < keep).astype(jnp.float32)
    out = gated_ffn(x, wg, wu, wd, gate)
    want = ref.gated_ffn_ref(x, wg, wu, wd, gate)
    # tolerance scales with the accumulation magnitude (outputs are
    # O(d*sqrt(f)) with unit-normal inputs; tile-order reassociation
    # perturbs the low bits)
    scale = float(jnp.max(jnp.abs(want))) + 1.0
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-5 * scale)


def test_gated_ffn_fully_pruned_is_zero():
    k = keys(3, 4)
    x, wg, wu, wd = rand(k[0], 16, 8), rand(k[1], 8, 24), \
        rand(k[2], 8, 24), rand(k[3], 24, 8)
    out = gated_ffn(x, wg, wu, wd, jnp.zeros(24))
    np.testing.assert_allclose(out, jnp.zeros((16, 8)), atol=1e-7)


def test_gated_ffn_tile_sizes_do_not_change_result():
    k = keys(4, 5)
    x = rand(k[0], 64, 32)
    wg, wu, wd = rand(k[1], 32, 96), rand(k[2], 32, 96), rand(k[3], 96, 32)
    gate = (jax.random.uniform(k[4], (96,)) < 0.6).astype(jnp.float32)
    a = gated_ffn(x, wg, wu, wd, gate, row_tile=16, chan_tile=24)
    b = gated_ffn(x, wg, wu, wd, gate, row_tile=64, chan_tile=96)
    scale = float(jnp.max(jnp.abs(b))) + 1.0
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5 * scale)


def test_gated_ffn_fuzz():
    rng = np.random.default_rng(0)
    for case in range(8):
        t = int(rng.integers(1, 96))
        d = int(rng.choice([8, 16, 32]))
        f = int(rng.choice([24, 48, 96]))
        k = keys(1000 + case, 5)
        x = rand(k[0], t, d)
        wg, wu, wd = rand(k[1], d, f), rand(k[2], d, f), rand(k[3], f, d)
        gate = (jax.random.uniform(k[4], (f,)) < rng.random()).astype(
            jnp.float32)
        np.testing.assert_allclose(
            gated_ffn(x, wg, wu, wd, gate),
            ref.gated_ffn_ref(x, wg, wu, wd, gate), rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------- attention --

ATTN_GRID = [
    # (H, Hkv, T, Dh, gate_pattern)
    (4, 4, 32, 16, "all"),
    (4, 2, 64, 16, "half"),
    (8, 8, 128, 32, "one"),
    (8, 2, 96, 8, "none"),
    (2, 1, 16, 4, "all"),
]


def make_gate(h, pattern, key):
    if pattern == "all":
        return jnp.ones(h)
    if pattern == "none":
        return jnp.zeros(h)
    if pattern == "one":
        return jnp.zeros(h).at[h // 2].set(1.0)
    return (jax.random.uniform(key, (h,)) < 0.5).astype(jnp.float32)


@pytest.mark.parametrize("h,hkv,t,dh,pattern", ATTN_GRID)
def test_gated_attention_matches_ref(h, hkv, t, dh, pattern):
    k = keys(h * 31 + t, 4)
    q = rand(k[0], h, t, dh)
    kk = rand(k[1], hkv, t, dh)
    vv = rand(k[2], hkv, t, dh)
    gate = make_gate(h, pattern, k[3])
    group = h // hkv
    out = gated_attention(q, jnp.repeat(kk, group, 0),
                          jnp.repeat(vv, group, 0), gate)
    want = ref.attention_ref(q, kk, vv, gate)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_attention_is_causal():
    # Changing a future token must not change earlier outputs.
    k = keys(9, 3)
    h, t, dh = 2, 32, 8
    q, kk, vv = rand(k[0], h, t, dh), rand(k[1], h, t, dh), \
        rand(k[2], h, t, dh)
    gate = jnp.ones(h)
    base = gated_attention(q, kk, vv, gate)
    kk2 = kk.at[:, -1, :].add(100.0)
    vv2 = vv.at[:, -1, :].add(100.0)
    pert = gated_attention(q, kk2, vv2, gate)
    np.testing.assert_allclose(base[:, :-1], pert[:, :-1], rtol=1e-5,
                               atol=1e-5)
    assert not np.allclose(base[:, -1], pert[:, -1])


def test_attention_query_tiling_invariant():
    k = keys(10, 3)
    h, t, dh = 4, 64, 16
    q, kk, vv = rand(k[0], h, t, dh), rand(k[1], h, t, dh), \
        rand(k[2], h, t, dh)
    gate = jnp.ones(h)
    a = gated_attention(q, kk, vv, gate, q_tile=16, key_tile=16)
    b = gated_attention(q, kk, vv, gate, q_tile=64, key_tile=64)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


DECODE_GRID = [
    (4, 2, 32, 16, 5),
    (8, 8, 64, 8, 63),
    (2, 1, 16, 4, 1),
]


@pytest.mark.parametrize("h,hkv,s,dh,length", DECODE_GRID)
def test_decode_attention_matches_ref(h, hkv, s, dh, length):
    k = keys(h * 13 + s, 4)
    q = rand(k[0], h, dh)
    kc = rand(k[1], hkv, s, dh)
    vc = rand(k[2], hkv, s, dh)
    gate = make_gate(h, "half", k[3])
    valid = (jnp.arange(s) < length).astype(jnp.float32)
    group = h // hkv
    out = decode_attention(q, jnp.repeat(kc, group, 0),
                           jnp.repeat(vc, group, 0), valid, gate)
    want = ref.decode_attention_ref(q, kc, vc, jnp.int32(length), gate)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_decode_ignores_invalid_rows():
    k = keys(11, 3)
    h, s, dh = 2, 16, 4
    q = rand(k[0], h, dh)
    kc, vc = rand(k[1], h, s, dh), rand(k[2], h, s, dh)
    valid = (jnp.arange(s) < 4).astype(jnp.float32)
    gate = jnp.ones(h)
    base = decode_attention(q, kc, vc, valid, gate)
    # garbage beyond the valid length must not matter
    kc2 = kc.at[:, 10:, :].set(1e6)
    vc2 = vc.at[:, 10:, :].set(-1e6)
    pert = decode_attention(q, kc2, vc2, valid, gate)
    np.testing.assert_allclose(base, pert, rtol=1e-6, atol=1e-6)


def test_rmsnorm_ref_unit_norm():
    k = keys(12, 1)
    x = rand(k[0], 8, 32) * 10.0
    out = ref.rmsnorm_ref(x, jnp.ones(32))
    rms = jnp.sqrt(jnp.mean(out * out, axis=-1))
    np.testing.assert_allclose(rms, jnp.ones(8), rtol=1e-3)

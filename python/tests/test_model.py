"""L2 correctness: model entry points, gating semantics, cross-entry
consistency (decode vs full forward), corpus properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus as C
from compile import model as M


@pytest.fixture(scope="module")
def tiny():
    cfg = M.RAP_TINY
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    plist = [params[n] for n, _ in M.param_specs(cfg)]
    return cfg, params, plist


def gates(cfg, hg=1.0, fg=1.0):
    return (jnp.full((cfg.n_layers, cfg.n_heads), hg, jnp.float32),
            jnp.full((cfg.n_layers, cfg.d_ff), fg, jnp.float32))


def test_param_specs_cover_init(tiny):
    cfg, params, _ = tiny
    specs = M.param_specs(cfg)
    assert set(params) == {n for n, _ in specs}
    for n, shape in specs:
        assert params[n].shape == shape


def test_score_pallas_equals_ref(tiny):
    cfg, _, plist = tiny
    hg, fg = gates(cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    mask = jnp.ones((2, 32), jnp.float32)
    n1, c1 = M.make_score_fn(cfg, True)(*plist, tok, mask, hg, fg)
    n2, c2 = M.make_score_fn(cfg, False)(*plist, tok, mask, hg, fg)
    np.testing.assert_allclose(n1, n2, rtol=1e-4)
    np.testing.assert_allclose(c1, c2)
    # mask counts exclude position 0
    np.testing.assert_allclose(c1, np.full(2, 31.0))


def test_loss_mask_selects_positions(tiny):
    cfg, _, plist = tiny
    hg, fg = gates(cfg)
    tok = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 0, cfg.vocab)
    full_mask = jnp.ones((1, 16), jnp.float32)
    half_mask = full_mask.at[:, :8].set(0.0)
    sf = M.make_score_fn(cfg, False)
    n_full, c_full = sf(*plist, tok, full_mask, hg, fg)
    n_half, c_half = sf(*plist, tok, half_mask, hg, fg)
    assert c_half[0] == 8.0 and c_full[0] == 15.0
    assert n_half[0] < n_full[0]


def test_gating_off_equals_residual_only(tiny):
    cfg, params, plist = tiny
    hg0, fg0 = gates(cfg, 0.0, 0.0)
    tok = jax.random.randint(jax.random.PRNGKey(3), (8,), 0, cfg.vocab)
    h, _, _ = M._forward_seq(cfg, params, tok, hg0, fg0,
                             use_pallas=False, collect=False)
    # with all blocks gated off the pre-norm residual stream is just the
    # embedding, so hidden = rmsnorm(embedding)
    want = M.ref.rmsnorm_ref(params["embed"][tok], params["norm_f"],
                             cfg.norm_eps)
    np.testing.assert_allclose(h, want, rtol=1e-5, atol=1e-6)


def test_decode_matches_full_forward(tiny):
    cfg, params, plist = tiny
    hg, fg = gates(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 20), 0, cfg.vocab)
    pf = M.make_prefill_fn(cfg)
    dc = M.make_decode_fn(cfg)
    logits, kc, vc = pf(*plist, toks[:, :16], hg, fg)
    lg = logits
    for i in range(3):
        lg, kc, vc = dc(*plist, toks[:, 16 + i],
                        jnp.array([16 + i], jnp.int32), kc, vc, hg, fg)
    h, _, _ = M._forward_seq(cfg, params, toks[0, :19], hg, fg,
                             use_pallas=False, collect=False)
    want = M._logits(cfg, params, h[-1:])
    np.testing.assert_allclose(lg, want, rtol=1e-4, atol=1e-4)


def test_decode_per_sequence_positions(tiny):
    # two sequences at different positions must decode independently
    cfg, params, plist = tiny
    hg, fg = gates(cfg)
    pf = M.make_prefill_fn(cfg)
    dc = M.make_decode_fn(cfg)
    t1 = jax.random.randint(jax.random.PRNGKey(5), (1, 16), 0, cfg.vocab)
    t2 = jax.random.randint(jax.random.PRNGKey(6), (1, 8), 0, cfg.vocab)
    _, k1, v1 = pf(*plist, t1, hg, fg)
    _, k2, v2 = pf(*plist, t2, hg, fg)
    kc = jnp.concatenate([k1, k2], axis=1)
    vc = jnp.concatenate([v1, v2], axis=1)
    nxt = jnp.array([3, 5], jnp.int32)
    pos = jnp.array([16, 8], jnp.int32)
    lg, _, _ = dc(*plist, nxt, pos, kc, vc, hg, fg)
    # reference: decode each alone at b=1
    lg1, _, _ = dc(*plist, nxt[:1], pos[:1], k1, v1, hg, fg)
    lg2, _, _ = dc(*plist, nxt[1:], pos[1:], k2, v2, hg, fg)
    np.testing.assert_allclose(lg[0], lg1[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(lg[1], lg2[0], rtol=1e-4, atol=1e-4)


def test_probe_shapes_and_ranges(tiny):
    cfg, _, plist = tiny
    hg, fg = gates(cfg)
    tok = jax.random.randint(jax.random.PRNGKey(7), (2, 24), 0, cfg.vocab)
    a, f, hn, cn = M.make_probe_fn(cfg)(*plist, tok, hg, fg)
    assert a.shape == (cfg.n_layers,)
    assert f.shape == (cfg.n_layers,)
    assert hn.shape == (cfg.n_layers, cfg.n_heads)
    assert cn.shape == (cfg.n_layers, cfg.d_ff)
    assert jnp.all(a <= 1.0 + 1e-5) and jnp.all(a >= -1.0 - 1e-5)
    assert jnp.all(hn >= 0) and jnp.all(cn >= 0)


# ------------------------------------------------------------- corpus --

def test_chain_rows_stochastic():
    chain = C.build_chain(64, seed=1)
    np.testing.assert_allclose(chain.sum(-1), np.ones(64), rtol=1e-5)
    assert chain.min() >= 0


def test_sample_deterministic():
    chain = C.build_chain(64, seed=1)
    a = C.sample_tokens(chain, 500, seed=2)
    b = C.sample_tokens(chain, 500, seed=2)
    np.testing.assert_array_equal(a, b)
    assert a.max() < 64


def test_copy_rule_creates_lag_correlation():
    chain = C.build_chain(64, seed=1)
    toks = C.sample_tokens(chain, 20_000, seed=3)
    lag = C.COPY_LAG
    match = np.mean(toks[lag:] == toks[:-lag])
    # copy_p of positions copy exactly; chance matches add a little
    assert match > C.COPY_P * 0.8, match


def test_next_token_dist_is_normalized():
    chain = C.build_chain(32, seed=4)
    ctx = C.sample_tokens(chain, 10, seed=5)
    d = C.next_token_dist(chain, ctx)
    assert abs(d.sum() - 1.0) < 1e-6
    # the copy target has at least copy_p mass
    assert d[int(ctx[len(ctx) - C.COPY_LAG])] >= C.COPY_P - 1e-6


def test_shifted_chain_higher_entropy():
    chain = C.build_chain(64, seed=6)
    shifted = C.shifted_chain(chain)
    ent = lambda m: float(-(m * np.log(m + 1e-12)).sum(-1).mean())
    assert ent(shifted) > ent(chain)

"""L2: the gated transformer LM (JAX, build-time only).

A single compiled HLO must serve *every* pruning configuration RAP's
controller can pick, so the forward pass takes two multiplier tensors:

  head_gate f32[L, H]   per-head attention gates (a pruned MHA block is a
                        row of zeros: its output vanishes and — in the L3
                        memory model — its KV cache is never allocated)
  ffn_gate  f32[L, F]   per-FFN-channel gates (a pruned FFN block is a row
                        of zeros; channel-granular baselines such as
                        LLMPruner-sim / SliceGPT-sim gate subsets)

Architecture: pre-norm decoder (RMSNorm), rotary embeddings, SwiGLU FFN,
optional GQA (n_kv_heads < n_heads), tied input/output embedding — the
Llama-family shape the paper evaluates.

Entry points lowered by ``aot.py`` (HLO text → Rust/PJRT):
  score   — per-sequence masked NLL (perplexity + MCQ scoring + GSI)
  probe   — per-block cosine-similarity / activation-norm statistics that
            the Rust baselines (ShortGPT, MHA-Drop, FFN-Skip, LLMPruner-sim)
            consume
  prefill — single-sequence prompt pass producing the KV cache
  decode  — batched single-token step with per-sequence positions

Weights are HLO *parameters* (never baked constants) in the fixed order of
``param_specs``; Rust loads ``weights.bin`` via the manifest.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.attention import decode_attention, gated_attention
from compile.kernels.gated_ffn import gated_ffn


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture description (mirrored by rust/src/model_meta)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    max_seq: int
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


# The two model families we reproduce the paper's tables with (see
# DESIGN.md §6 for the Llama-7B → rap-small substitution argument).
RAP_SMALL = ModelConfig(name="rap-small", vocab=512, d_model=256,
                        n_layers=12, n_heads=8, n_kv_heads=8, d_ff=1024,
                        max_seq=256)
QWEN_SIM = ModelConfig(name="qwen-sim", vocab=512, d_model=256, n_layers=8,
                       n_heads=8, n_kv_heads=2, d_ff=768, max_seq=256)
RAP_TINY = ModelConfig(name="rap-tiny", vocab=64, d_model=64, n_layers=3,
                       n_heads=4, n_kv_heads=2, d_ff=128, max_seq=64)

CONFIGS = {c.name: c for c in (RAP_SMALL, QWEN_SIM, RAP_TINY)}


def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Fixed (name, shape) order — the HLO parameter order and the
    ``weights.bin`` layout both follow this list exactly."""
    L, D, F = cfg.n_layers, cfg.d_model, cfg.d_ff
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return [
        ("embed", (cfg.vocab, D)),
        ("norm_f", (D,)),
        ("attn_norm", (L, D)),
        ("wq", (L, D, H * Dh)),
        ("wk", (L, D, Hkv * Dh)),
        ("wv", (L, D, Hkv * Dh)),
        ("wo", (L, H * Dh, D)),
        ("ffn_norm", (L, D)),
        ("w_gate", (L, D, F)),
        ("w_up", (L, D, F)),
        ("w_down", (L, F, D)),
    ]


def init_params(cfg: ModelConfig, key: jax.Array) -> dict[str, jax.Array]:
    """Scaled-normal init (0.02, with 1/sqrt(2L) residual-out scaling)."""
    params = {}
    resid_scale = 1.0 / jnp.sqrt(2.0 * cfg.n_layers)
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name in ("norm_f", "attn_norm", "ffn_norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            w = jax.random.normal(sub, shape, jnp.float32) * 0.02
            if name in ("wo", "w_down"):
                w = w * resid_scale
            params[name] = w
    return params


def _rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x [..., T, Dh]; pos broadcastable to x's T axis."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[..., None] * freqs                       # [..., T, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


def _expand_kv(k: jax.Array, group: int) -> jax.Array:
    """[Hkv, ...] → [H, ...] by repeating each kv head ``group`` times."""
    return jnp.repeat(k, group, axis=0)


def _forward_seq(cfg: ModelConfig, params: dict, tokens: jax.Array,
                 head_gate: jax.Array, ffn_gate: jax.Array,
                 use_pallas: bool, collect: bool):
    """Full-sequence forward for ONE example.

    tokens [T] i32. Returns (hidden [T, D], stats or None, (k, v) caches
    [L, Hkv, T, Dh]).
    """
    T = tokens.shape[0]
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    group = H // Hkv
    pos = jnp.arange(T, dtype=jnp.float32)
    x = params["embed"][tokens]

    layer_xs = (
        {k: params[k] for k in ("attn_norm", "wq", "wk", "wv", "wo",
                                "ffn_norm", "w_gate", "w_up", "w_down")},
        head_gate, ffn_gate,
    )

    def body(x, inputs):
        lp, hg, fg = inputs
        a_in = ref.rmsnorm_ref(x, lp["attn_norm"], cfg.norm_eps)
        q = (a_in @ lp["wq"]).reshape(T, H, Dh).transpose(1, 0, 2)
        k = (a_in @ lp["wk"]).reshape(T, Hkv, Dh).transpose(1, 0, 2)
        v = (a_in @ lp["wv"]).reshape(T, Hkv, Dh).transpose(1, 0, 2)
        q = _rope(q, pos, cfg.rope_theta)
        k = _rope(k, pos, cfg.rope_theta)
        if use_pallas:
            heads = gated_attention(q, _expand_kv(k, group),
                                    _expand_kv(v, group), hg)
        else:
            heads = ref.attention_ref(q, k, v, hg)
        attn_out = heads.transpose(1, 0, 2).reshape(T, H * Dh) @ lp["wo"]
        x1 = x + attn_out
        f_in = ref.rmsnorm_ref(x1, lp["ffn_norm"], cfg.norm_eps)
        if use_pallas:
            ffn_out = gated_ffn(f_in, lp["w_gate"], lp["w_up"],
                                lp["w_down"], fg)
        else:
            ffn_out = ref.gated_ffn_ref(f_in, lp["w_gate"], lp["w_up"],
                                        lp["w_down"], fg)
        x2 = x1 + ffn_out

        stats = None
        if collect:
            def cos(a, b):
                num = jnp.sum(a * b, -1)
                den = (jnp.linalg.norm(a, axis=-1)
                       * jnp.linalg.norm(b, axis=-1))
                return jnp.mean(num / jnp.maximum(den, 1e-9))

            h_act = jax.nn.silu(f_in @ lp["w_gate"]) * (f_in @ lp["w_up"])
            stats = (
                cos(x, x1),                                    # attn_cos
                cos(x1, x2),                                   # ffn_cos
                jnp.mean(jnp.linalg.norm(heads, axis=-1), 1),  # head_norm [H]
                jnp.mean(jnp.abs(h_act), axis=0),              # chan_norm [F]
            )
        return x2, (stats, k, v)

    x, (stats, ks, vs) = jax.lax.scan(body, x, layer_xs)
    x = ref.rmsnorm_ref(x, params["norm_f"], cfg.norm_eps)
    return x, stats, (ks, vs)


def _logits(cfg: ModelConfig, params: dict, hidden: jax.Array) -> jax.Array:
    """Tied-embedding readout."""
    return hidden @ params["embed"].T


# --------------------------------------------------------------------------
# Lowered entry points. Each takes the flat parameter list first (in
# param_specs order), then runtime inputs — aot.py lowers them positionally.
# --------------------------------------------------------------------------

def make_score_fn(cfg: ModelConfig, use_pallas: bool = True):
    """(params…, tokens i32[B,T], loss_mask f32[B,T], head_gate, ffn_gate)
    → (per_seq_nll f32[B], per_seq_cnt f32[B]).

    ``loss_mask[b, t]`` weights the NLL of predicting ``tokens[b, t]`` from
    its prefix (position 0 can never be a target). Perplexity harness: mask
    = 1 everywhere except column 0; MCQ harness: mask = 1 on ending tokens.
    """
    names = [n for n, _ in param_specs(cfg)]

    def fn(*args):
        params = dict(zip(names, args[:len(names)]))
        tokens, loss_mask, head_gate, ffn_gate = args[len(names):]

        def one(tok, mask):
            h, _, _ = _forward_seq(cfg, params, tok, head_gate, ffn_gate,
                                   use_pallas, collect=False)
            logits = _logits(cfg, params, h)            # [T, V]
            logp = jax.nn.log_softmax(logits[:-1], axis=-1)
            tgt = tok[1:]
            nll = -jnp.take_along_axis(logp, tgt[:, None], axis=-1)[:, 0]
            m = mask[1:]
            return jnp.sum(nll * m), jnp.sum(m)

        nlls, cnts = jax.vmap(one)(tokens, loss_mask)
        return nlls, cnts

    return fn


def make_probe_fn(cfg: ModelConfig):
    """(params…, tokens i32[B,T], head_gate, ffn_gate) →
    (attn_cos f32[L], ffn_cos f32[L], head_norm f32[L,H], chan_norm f32[L,F])

    Block-redundancy statistics averaged over the batch; consumed by the
    Rust baseline importance scorers (ShortGPT / MHA-Drop / FFN-Skip use
    the cosine similarities, LLMPruner-sim the activation norms). Ref path
    only — diagnostics, not the serving hot path.
    """
    names = [n for n, _ in param_specs(cfg)]

    def fn(*args):
        params = dict(zip(names, args[:len(names)]))
        tokens, head_gate, ffn_gate = args[len(names):]

        def one(tok):
            _, stats, _ = _forward_seq(cfg, params, tok, head_gate,
                                       ffn_gate, use_pallas=False,
                                       collect=True)
            return stats

        a_cos, f_cos, h_norm, c_norm = jax.vmap(one)(tokens)
        return (jnp.mean(a_cos, 0), jnp.mean(f_cos, 0),
                jnp.mean(h_norm, 0), jnp.mean(c_norm, 0))

    return fn


def make_prefill_fn(cfg: ModelConfig, use_pallas: bool = True):
    """(params…, tokens i32[1,T], head_gate, ffn_gate) →
    (logits f32[1,V], k_cache f32[L,1,Hkv,S,Dh], v_cache …)

    Single-sequence prompt pass; caches are right-padded to S = max_seq so
    the Rust KV manager can splice them into decode batches.
    """
    names = [n for n, _ in param_specs(cfg)]
    S = cfg.max_seq

    def fn(*args):
        params = dict(zip(names, args[:len(names)]))
        tokens, head_gate, ffn_gate = args[len(names):]
        tok = tokens[0]
        T = tok.shape[0]
        h, _, (ks, vs) = _forward_seq(cfg, params, tok, head_gate, ffn_gate,
                                      use_pallas, collect=False)
        logits = _logits(cfg, params, h[-1:])           # [1, V]
        # ks/vs: [L, Hkv, T, Dh] → pad to [L, 1, Hkv, S, Dh]
        pad = [(0, 0), (0, 0), (0, S - T), (0, 0)]
        k_cache = jnp.pad(ks, pad)[:, None]
        v_cache = jnp.pad(vs, pad)[:, None]
        return logits, k_cache, v_cache

    return fn


def make_decode_fn(cfg: ModelConfig, use_pallas: bool = True):
    """(params…, token i32[B], pos i32[B], k_cache f32[L,B,Hkv,S,Dh],
    v_cache …, head_gate, ffn_gate) → (logits f32[B,V], k_cache', v_cache')

    One autoregressive step for a continuous-batching decode batch;
    ``pos[b]`` is the index the new token is written at (sequence b has
    pos[b] prior tokens in the cache).
    """
    names = [n for n, _ in param_specs(cfg)]
    S = cfg.max_seq
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    group = H // Hkv

    def fn(*args):
        params = dict(zip(names, args[:len(names)]))
        token, pos, k_cache, v_cache, head_gate, ffn_gate = args[len(names):]
        x = params["embed"][token]                      # [B, D]
        fpos = pos.astype(jnp.float32)

        layer_xs = (
            {k: params[k] for k in ("attn_norm", "wq", "wk", "wv", "wo",
                                    "ffn_norm", "w_gate", "w_up", "w_down")},
            head_gate, ffn_gate, k_cache, v_cache,
        )

        def body(x, inputs):
            lp, hg, fg, kc, vc = inputs                 # kc/vc [B,Hkv,S,Dh]
            a_in = ref.rmsnorm_ref(x, lp["attn_norm"], cfg.norm_eps)
            q = (a_in @ lp["wq"]).reshape(-1, H, Dh)    # [B, H, Dh]
            k = (a_in @ lp["wk"]).reshape(-1, Hkv, Dh)
            v = (a_in @ lp["wv"]).reshape(-1, Hkv, Dh)
            q = _rope(q, fpos[:, None], cfg.rope_theta)
            k = _rope(k, fpos[:, None], cfg.rope_theta)

            def upd(cache_b, new_b, p):
                return jax.lax.dynamic_update_slice(
                    cache_b, new_b[:, None, :], (0, p, 0))

            kc = jax.vmap(upd)(kc, k, pos)
            vc = jax.vmap(upd)(vc, v, pos)
            valid = (jnp.arange(S)[None, :] <= pos[:, None]).astype(
                jnp.float32)                            # [B, S]

            def attn_one(q_b, kc_b, vc_b, valid_b):
                kx = _expand_kv(kc_b, group)
                vx = _expand_kv(vc_b, group)
                if use_pallas:
                    return decode_attention(q_b, kx, vx, valid_b, hg)
                length = jnp.sum(valid_b).astype(jnp.int32)
                return ref.decode_attention_ref(q_b, kc_b, vc_b, length, hg)

            heads = jax.vmap(attn_one)(q, kc, vc, valid)  # [B, H, Dh]
            attn_out = heads.reshape(-1, H * Dh) @ lp["wo"]
            x1 = x + attn_out
            f_in = ref.rmsnorm_ref(x1, lp["ffn_norm"], cfg.norm_eps)
            ffn_out = ref.gated_ffn_ref(f_in, lp["w_gate"], lp["w_up"],
                                        lp["w_down"], fg)
            return x1 + ffn_out, (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(body, x, layer_xs)
        x = ref.rmsnorm_ref(x, params["norm_f"], cfg.norm_eps)
        return _logits(cfg, params, x), k_new, v_new

    return fn


def make_loss_fn(cfg: ModelConfig):
    """Training loss (build-time only): mean next-token NLL over the batch."""

    def loss(params: dict, tokens: jax.Array) -> jax.Array:
        hg = jnp.ones((cfg.n_layers, cfg.n_heads), jnp.float32)
        fg = jnp.ones((cfg.n_layers, cfg.d_ff), jnp.float32)

        def one(tok):
            h, _, _ = _forward_seq(cfg, params, tok, hg, fg,
                                   use_pallas=False, collect=False)
            logits = _logits(cfg, params, h)
            logp = jax.nn.log_softmax(logits[:-1], axis=-1)
            nll = -jnp.take_along_axis(logp, tok[1:, None], axis=-1)[:, 0]
            return jnp.mean(nll)

        return jnp.mean(jax.vmap(one)(tokens))

    return loss

"""Build-time training of the substitute LMs (see DESIGN.md §6).

Hand-rolled Adam (no optax dependency), jitted update step, linear warmup +
cosine decay. This runs exactly once under ``make artifacts``; nothing here
is on the serving path. The point is to give the model a *learned*
distribution so that the paper's quantities — perplexity deltas under block
removal, GSI orderings, commonsense-sim accuracy — are meaningful signals
rather than noise around a random-init model.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import ModelConfig, init_params, make_loss_fn


def batches(tokens: np.ndarray, batch: int, seqlen: int, steps: int,
            seed: int):
    """Yield [batch, seqlen] i32 windows sampled uniformly from the stream."""
    rng = np.random.default_rng(seed)
    hi = len(tokens) - seqlen - 1
    for _ in range(steps):
        idx = rng.integers(0, hi, size=batch)
        yield np.stack([tokens[i:i + seqlen] for i in idx]).astype(np.int32)


def train(cfg: ModelConfig, tokens: np.ndarray, steps: int = 250,
          batch: int = 8, seqlen: int = 128, lr: float = 3e-3,
          warmup: int = 20, seed: int = 0, log_every: int = 25):
    """Train and return (params, loss_history)."""
    loss_fn = make_loss_fn(cfg)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def update(params, m, v, batch_tokens, step):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch_tokens)
        t = step + 1.0
        sched = jnp.minimum(t / warmup, 1.0) * 0.5 * (
            1.0 + jnp.cos(jnp.pi * jnp.minimum(t / steps, 1.0)))
        lr_t = lr * jnp.maximum(sched, 0.05)
        b1, b2, eps = 0.9, 0.95, 1e-8
        m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
        v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
        mh = jax.tree.map(lambda a: a / (1 - b1 ** t), m)
        vh = jax.tree.map(lambda a: a / (1 - b2 ** t), v)
        params = jax.tree.map(
            lambda p, a, b: p - lr_t * a / (jnp.sqrt(b) + eps),
            params, mh, vh)
        return params, m, v, loss

    history = []
    t0 = time.time()
    for step, bt in enumerate(batches(tokens, batch, seqlen, steps, seed)):
        params, m, v, loss = update(params, m, v, jnp.asarray(bt),
                                    jnp.asarray(float(step)))
        if step % log_every == 0 or step == steps - 1:
            lv = float(loss)
            history.append((step, lv))
            print(f"  [{cfg.name}] step {step:4d} loss {lv:.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return params, history

"""AOT pipeline: corpus → training → weights.bin + manifest → HLO text.

Runs exactly once (``make artifacts``); Python never appears on the Rust
request path. Interchange format is HLO *text*, not a serialized
HloModuleProto — jax ≥ 0.5 emits protos with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifact layout (consumed by rust/src/runtime + rust/src/corpus):

  artifacts/
    corpus/{chain.bin, chain_ptb.bin, train.bin, wiki.bin, ptb.bin,
            alpaca.bin, meta.json}
    <model>/
      manifest.json     — config + param table + entry I/O shapes
      weights.bin       — f32 little-endian, param_specs order
      train_log.json    — loss curve of the build-time training run
      <entry>.hlo.txt   — one per (entry point, shape bucket)
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import corpus as corpus_mod
from compile import model as M
from compile import train as train_mod

# Shape buckets lowered per model. GSI + Table 1 use score@(4,128); Fig 4
# sweeps T; serving uses prefill@(1,T) and decode@(B).
SCORE_BUCKETS = [(1, 128), (4, 64), (4, 128), (4, 256), (8, 128)]
PROBE_BUCKETS = [(4, 128)]
PREFILL_T = [16, 32, 64, 128]
DECODE_B = [1, 2, 4, 8]

TRAIN_PLAN = {
    # name → (steps, batch, seqlen). Step counts sized so the induction
    # (copy-rule) circuit emerges — see corpus.py docstring.
    "rap-small": (600, 8, 96),
    "qwen-sim": (420, 8, 96),
    "rap-tiny": (600, 8, 48),
}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so Rust
    unwraps a single tuple output)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_plan(cfg: M.ModelConfig):
    """name → (fn, [input descriptors], [output descriptors])."""
    L, H, F = cfg.n_layers, cfg.n_heads, cfg.d_ff
    Hkv, S, Dh = cfg.n_kv_heads, cfg.max_seq, cfg.head_dim
    gates = [("head_gate", (L, H), "f32"), ("ffn_gate", (L, F), "f32")]
    plan = {}
    for b, t in SCORE_BUCKETS:
        if t > cfg.max_seq:
            continue
        plan[f"score_b{b}_t{t}"] = (
            M.make_score_fn(cfg),
            [("tokens", (b, t), "i32"), ("loss_mask", (b, t), "f32")] + gates,
            [("nll", (b,), "f32"), ("cnt", (b,), "f32")],
        )
    for b, t in PROBE_BUCKETS:
        t = min(t, cfg.max_seq)   # small models probe at their max_seq
        plan[f"probe_b{b}_t{t}"] = (
            M.make_probe_fn(cfg),
            [("tokens", (b, t), "i32")] + gates,
            [("attn_cos", (L,), "f32"), ("ffn_cos", (L,), "f32"),
             ("head_norm", (L, H), "f32"), ("chan_norm", (L, F), "f32")],
        )
    for t in PREFILL_T:
        if t > cfg.max_seq:
            continue
        plan[f"prefill_t{t}"] = (
            M.make_prefill_fn(cfg),
            [("tokens", (1, t), "i32")] + gates,
            [("logits", (1, cfg.vocab), "f32"),
             ("k_cache", (L, 1, Hkv, S, Dh), "f32"),
             ("v_cache", (L, 1, Hkv, S, Dh), "f32")],
        )
    for b in DECODE_B:
        plan[f"decode_b{b}"] = (
            M.make_decode_fn(cfg),
            [("token", (b,), "i32"), ("pos", (b,), "i32"),
             ("k_cache", (L, b, Hkv, S, Dh), "f32"),
             ("v_cache", (L, b, Hkv, S, Dh), "f32")] + gates,
            [("logits", (b, cfg.vocab), "f32"),
             ("k_cache", (L, b, Hkv, S, Dh), "f32"),
             ("v_cache", (L, b, Hkv, S, Dh), "f32")],
        )
    return plan


_DT = {"f32": jnp.float32, "i32": jnp.int32}


def build_model(cfg: M.ModelConfig, tokens: np.ndarray | None,
                out_root: pathlib.Path, seed: int = 0,
                reuse_weights: bool = False):
    out = out_root / cfg.name
    out.mkdir(parents=True, exist_ok=True)

    weights_path = out / "weights.bin"
    if reuse_weights and weights_path.exists():
        print(f"[aot] reusing weights for {cfg.name}", flush=True)
        raw = np.fromfile(weights_path, np.float32)
        params, off = {}, 0
        for name, shape in M.param_specs(cfg):
            n = int(np.prod(shape))
            params[name] = jnp.asarray(raw[off:off + n].reshape(shape))
            off += n
    else:
        steps, batch, seqlen = TRAIN_PLAN[cfg.name]
        if tokens is None:
            # rap-tiny trains on its own micro-chain (vocab differs).
            chain = corpus_mod.build_chain(cfg.vocab, seed=4321)
            tokens = corpus_mod.sample_tokens(chain, 60_000, seed=4322)
        print(f"[aot] training {cfg.name} ({steps} steps, B={batch}, "
              f"T={seqlen})", flush=True)
        params, history = train_mod.train(cfg, tokens, steps=steps,
                                          batch=batch, seqlen=seqlen,
                                          seed=seed)
        (out / "train_log.json").write_text(json.dumps(
            {"steps": steps, "batch": batch, "seqlen": seqlen,
             "loss": history}, indent=2))

    # weights.bin + param table
    specs = M.param_specs(cfg)
    offset = 0
    param_table = []
    with open(out / "weights.bin", "wb") as f:
        for name, shape in specs:
            arr = np.asarray(params[name], np.float32)
            assert arr.shape == shape, (name, arr.shape, shape)
            f.write(arr.tobytes())
            param_table.append({"name": name, "shape": list(shape),
                                "dtype": "f32", "offset": offset,
                                "nbytes": arr.nbytes})
            offset += arr.nbytes

    # lower entries
    pspecs = [_spec(shape) for _, shape in specs]
    entries = {}
    for name, (fn, inputs, outputs) in entry_plan(cfg).items():
        t0 = time.time()
        ispecs = [_spec(shape, _DT[dt]) for _, shape, dt in inputs]
        # keep_unused: the probe entry does not read norm_f; without this
        # jax prunes it from the HLO signature and the Rust runtime's
        # uniform weights-first calling convention breaks.
        lowered = jax.jit(fn, keep_unused=True).lower(*pspecs, *ispecs)
        text = to_hlo_text(lowered)
        (out / f"{name}.hlo.txt").write_text(text)
        entries[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [{"name": n, "shape": list(s), "dtype": d}
                       for n, s, d in inputs],
            "outputs": [{"name": n, "shape": list(s), "dtype": d}
                        for n, s, d in outputs],
        }
        print(f"[aot]   lowered {cfg.name}/{name} "
              f"({len(text) / 1e6:.1f} MB, {time.time() - t0:.1f}s)",
              flush=True)

    manifest = {
        "model": cfg.to_json(),
        "weights_file": "weights.bin",
        "params": param_table,
        "entries": entries,
    }
    (out / "manifest.json").write_text(json.dumps(manifest, indent=2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="rap-tiny,rap-small,qwen-sim")
    ap.add_argument("--skip-corpus", action="store_true")
    ap.add_argument("--reuse-weights", action="store_true",
                    help="load existing weights.bin instead of training "
                         "(re-lowers entries only)")
    args = ap.parse_args()
    out_root = pathlib.Path(args.out)

    if not args.skip_corpus:
        print("[aot] generating corpus", flush=True)
        train_tokens = corpus_mod.generate_all(out_root / "corpus",
                                               vocab=M.RAP_SMALL.vocab)
    else:
        train_tokens = np.fromfile(out_root / "corpus" / "train.bin",
                                   np.uint16)

    for name in args.models.split(","):
        cfg = M.CONFIGS[name]
        toks = None if cfg.vocab != M.RAP_SMALL.vocab else train_tokens
        build_model(cfg, toks, out_root, reuse_weights=args.reuse_weights)
    print("[aot] done", flush=True)


if __name__ == "__main__":
    main()

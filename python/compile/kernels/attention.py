"""L1 Pallas kernels: gated causal attention (prefill) + decode attention.

The paper prunes whole MHA blocks because they *create* the KV cache
(§2.1); the attention kernel therefore takes a per-head gate so a pruned
head (or a whole pruned layer: all heads zero) contributes nothing and —
critically for the memory model — allocates no KV rows in the L3 cache
manager.

TPU adaptation of the usual CUDA flash kernel:
  * grid = (heads, query tiles); the online-softmax loop walks key tiles
    held in VMEM — BlockSpec streams [1, S, Dh] per head rather than a
    threadblock's shared-memory staging.
  * accumulators (m, l, acc) live in registers/VMEM scratch across the
    fori_loop, the standard flash recurrence.

GQA is handled one level up (L2 expands KV heads to query heads before the
call) to keep the kernel's index map affine. ``interpret=True`` throughout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, g_ref, o_ref, *, key_tile: int,
                 seq_len: int, q_len: int):
    """One (head, query-tile) grid step with online softmax over key tiles.

    q_ref [1, Tq, Dh]; k_ref/v_ref [1, S, Dh]; g_ref [1, 1]; o_ref [1, Tq, Dh].
    """
    i = pl.program_id(1)
    tq = q_ref.shape[1]
    dh = q_ref.shape[2]
    q = q_ref[0, :, :]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    qpos = i * tq + jax.lax.iota(jnp.int32, tq) + (seq_len - q_len)

    n_kt = seq_len // key_tile

    def body(kt, carry):
        m_prev, l_prev, acc = carry
        k = jax.lax.dynamic_slice(k_ref[0, :, :], (kt * key_tile, 0),
                                  (key_tile, dh))
        v = jax.lax.dynamic_slice(v_ref[0, :, :], (kt * key_tile, 0),
                                  (key_tile, dh))
        s = (q @ k.T) * scale                      # [Tq, Kt]
        kpos = kt * key_tile + jax.lax.iota(jnp.int32, key_tile)
        causal = kpos[None, :] <= qpos[:, None]
        s = jnp.where(causal, s, _NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + p @ v
        return m_new, l_new, acc

    m0 = jnp.full((tq,), _NEG_INF, q.dtype)
    l0 = jnp.zeros((tq,), q.dtype)
    a0 = jnp.zeros((tq, dh), q.dtype)
    _, l, acc = jax.lax.fori_loop(0, n_kt, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-20)[:, None]
    o_ref[0, :, :] = out * g_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("q_tile", "key_tile"))
def gated_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    head_gate: jax.Array, q_tile: int = 128,
                    key_tile: int = 128) -> jax.Array:
    """Causal multi-head attention with per-head gates via Pallas.

    q [H, T, Dh]; k, v [H, S, Dh] (already expanded to query heads);
    head_gate [H]. Returns [H, T, Dh]. Matches ``ref.attention_ref``
    (after GQA expansion) exactly.
    """
    h, t, dh = q.shape
    s = k.shape[1]

    def pick(n, target):
        w = min(n, target)
        while n % w != 0:
            w -= 1
        return w

    tq = pick(t, q_tile)
    kt = pick(s, key_tile)
    grid = (h, t // tq)
    gate2d = head_gate.reshape(h, 1)
    kern = functools.partial(_attn_kernel, key_tile=kt, seq_len=s, q_len=t)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, dh), lambda hh, i: (hh, i, 0)),
            pl.BlockSpec((1, s, dh), lambda hh, i: (hh, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda hh, i: (hh, 0, 0)),
            pl.BlockSpec((1, 1), lambda hh, i: (hh, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, dh), lambda hh, i: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t, dh), q.dtype),
        interpret=True,
    )(q, k, v, gate2d)


def _decode_kernel(q_ref, k_ref, v_ref, m_ref, g_ref, o_ref):
    """One head of single-token decode attention.

    q_ref [1, Dh]; k_ref/v_ref [1, S, Dh]; m_ref [1, S] validity mask
    (1 = valid cache row); g_ref [1, 1]; o_ref [1, Dh].
    """
    dh = q_ref.shape[1]
    q = q_ref[0, :]
    k = k_ref[0, :, :]
    v = v_ref[0, :, :]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    s = (k @ q) * scale                           # [S]
    s = jnp.where(m_ref[0, :] > 0, s, _NEG_INF)
    m = jnp.max(s)
    p = jnp.exp(s - m)
    out = (p @ v) / jnp.maximum(jnp.sum(p), 1e-20)
    o_ref[0, :] = out * g_ref[0, 0]


@jax.jit
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     valid_mask: jax.Array, head_gate: jax.Array) -> jax.Array:
    """Single-token decode attention with per-head gates via Pallas.

    q [H, Dh]; k_cache, v_cache [H, S, Dh] (expanded to query heads);
    valid_mask [S] (1.0 for rows < current length); head_gate [H].
    Returns [H, Dh]. Matches ``ref.decode_attention_ref``.
    """
    h, dh = q.shape
    s = k_cache.shape[1]
    mask2d = jnp.broadcast_to(valid_mask.reshape(1, s), (h, s))
    gate2d = head_gate.reshape(h, 1)
    return pl.pallas_call(
        _decode_kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, dh), lambda hh: (hh, 0)),
            pl.BlockSpec((1, s, dh), lambda hh: (hh, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda hh: (hh, 0, 0)),
            pl.BlockSpec((1, s), lambda hh: (hh, 0)),
            pl.BlockSpec((1, 1), lambda hh: (hh, 0)),
        ],
        out_specs=pl.BlockSpec((1, dh), lambda hh: (hh, 0)),
        out_shape=jax.ShapeDtypeStruct((h, dh), q.dtype),
        interpret=True,
    )(q, k_cache, v_cache, mask2d, gate2d)

"""L1 Pallas kernel: gated SwiGLU FFN — the parameter-heavy hot spot.

The paper's memory analysis (§2.1) shows FFNs hold ~2/3 of parameters, so
the FFN matmul chain is the compute hot path once KV cache is bounded.
This kernel expresses the TPU schedule the paper's CUDA code expressed with
threadblocks:

  * grid = (row tiles, FFN-channel tiles): each step owns a [Tm, Fn]
    channel slab in VMEM — the HBM→VMEM pipeline BlockSpec describes.
  * the MXU sees [Tm, D] @ [D, Fn] and [Tm, Fn] @ [Fn, D] tiles, all
    multiples of the 128-lane systolic width when shapes allow.
  * per-channel gating multiplies whole channel tiles; on real hardware a
    fully-zero gate tile is a skippable grid step (predicated out), which
    is exactly how structured channel pruning converts to FLOP savings.

Runs under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); the lowered HLO is plain ops and compiles natively.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, g_ref, o_ref):
    """One (row-tile, channel-tile) grid step.

    x_ref  [Tm, D]   row tile of activations
    wg_ref [D, Fn]   gate-projection channel slab
    wu_ref [D, Fn]   up-projection channel slab
    wd_ref [Fn, D]   down-projection channel slab
    g_ref  [1, Fn]   channel gate slab (0 = pruned channel)
    o_ref  [Tm, D]   output row tile, accumulated over channel tiles
    """
    j = pl.program_id(1)
    x = x_ref[...]
    h = jax.nn.silu(x @ wg_ref[...]) * (x @ wu_ref[...])
    h = h * g_ref[0, :][None, :]
    part = h @ wd_ref[...]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = part

    @pl.when(j > 0)
    def _acc():
        o_ref[...] += part


def _pick_tile(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is ≤ target (MXU-friendly when n is
    a multiple of 128)."""
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("row_tile", "chan_tile"))
def gated_ffn(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
              w_down: jax.Array, chan_gate: jax.Array,
              row_tile: int = 128, chan_tile: int = 256) -> jax.Array:
    """SwiGLU FFN with per-channel gating via Pallas.

    Shapes: x [T, D]; w_gate/w_up [D, F]; w_down [F, D]; chan_gate [F].
    Returns [T, D]. Matches ``ref.gated_ffn_ref`` exactly.
    """
    t, d = x.shape
    f = w_gate.shape[1]
    tm = _pick_tile(t, row_tile)
    fn = _pick_tile(f, chan_tile)
    grid = (t // tm, f // fn)
    gate2d = chan_gate.reshape(1, f)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, fn), lambda i, j: (0, j)),
            pl.BlockSpec((d, fn), lambda i, j: (0, j)),
            pl.BlockSpec((fn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, fn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x, w_gate, w_up, w_down, gate2d)

"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact (up to float assoc.)
counterpart here; ``python/tests/test_kernel.py`` asserts allclose between
the two across a hypothesis-driven sweep of shapes/dtypes. These refs are
also what the L2 model uses when ``use_pallas=False`` (debug path).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gated_ffn_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                  w_down: jax.Array, chan_gate: jax.Array) -> jax.Array:
    """SwiGLU FFN with per-channel gating.

    x:         [T, D]
    w_gate:    [D, F]    (SwiGLU "gate" projection)
    w_up:      [D, F]
    w_down:    [F, D]
    chan_gate: [F]       multiplicative channel mask (0 = pruned channel)

    Returns [T, D].
    """
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    h = h * chan_gate[None, :]
    return h @ w_down


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  head_gate: jax.Array, causal: bool = True) -> jax.Array:
    """Multi-head attention with per-head gating.

    q: [H, T, Dh]; k, v: [Hkv, S, Dh]; head_gate: [H].
    GQA: query head h attends to kv head h // (H // Hkv).
    Returns [H, T, Dh] with gated heads zeroed.
    """
    hq, t, dh = q.shape
    hkv, s, _ = k.shape
    group = hq // hkv
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    logits = jnp.einsum("htd,hsd->hts", q, k) * scale
    if causal:
        # positions: query i (absolute s - t + i) sees keys <= that position
        qpos = jnp.arange(t)[:, None] + (s - t)
        kpos = jnp.arange(s)[None, :]
        mask = kpos <= qpos
        logits = jnp.where(mask[None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hts,hsd->htd", probs, v)
    return out * head_gate[:, None, None]


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         length: jax.Array, head_gate: jax.Array) -> jax.Array:
    """Single-token decode attention against a cache.

    q: [H, Dh]; k_cache, v_cache: [Hkv, S, Dh]; length: scalar i32 (#valid
    cache rows, including the current token already written); head_gate: [H].
    Returns [H, Dh].
    """
    hq, dh = q.shape
    hkv, s, _ = k_cache.shape
    group = hq // hkv
    k = jnp.repeat(k_cache, group, axis=0)
    v = jnp.repeat(v_cache, group, axis=0)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    logits = jnp.einsum("hd,hsd->hs", q, k) * scale
    valid = jnp.arange(s)[None, :] < length
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hs,hsd->hd", probs, v)
    return out * head_gate[:, None]


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm over the last axis."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w

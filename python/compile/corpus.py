"""Synthetic structured corpus — the WikiText2/PTB/Alpaca substitute.

The paper's experiments need (a) a training distribution the model can
actually learn in a few hundred build-time steps (so block removal produces
*graded* perplexity damage, not noise), and (b) in-domain vs. shifted eval
splits. We use a Markov chain with an induction component:

  * a sparse row-stochastic transition matrix over the vocab (each token
    prefers ~20 Zipf-weighted successors) — learnable by the FFN/embedding
    path alone (bigram statistics);
  * with probability COPY_P the next token instead *copies* the token
    COPY_LAG positions back — predictable only through attention, which
    makes MHA blocks genuinely load-bearing (the paper's Fig. 4 block
    heterogeneity needs both pathways to matter);
  * splits: ``train``/``wiki-sim`` share the chain; ``ptb-sim`` interpolates
    the chain with uniform noise (out-of-domain, higher entropy — mirrors
    the paper's WikiText2→PTB gap); ``alpaca-sim`` is a fresh sample from
    the training chain (the GSI calibration corpus).

The chain matrix is exported to ``artifacts/corpus/chain.bin`` so the Rust
side can deterministically generate MCQ tasks (commonsense-sim suite) and
extra eval data with the same distribution. Everything is seeded.
"""

from __future__ import annotations

import json
import pathlib

import numpy as np

PTB_NOISE = 0.35          # uniform interpolation weight for the shifted split
BRANCH = 20               # preferred successors per token
COPY_P = 0.35             # probability the next token copies from the past
COPY_LAG = 4              # copy distance (attention has to reach back)


def build_chain(vocab: int, seed: int = 1234) -> np.ndarray:
    """Row-stochastic transition matrix [V, V], f32."""
    rng = np.random.default_rng(seed)
    chain = np.zeros((vocab, vocab), np.float32)
    ranks = np.arange(1, BRANCH + 1, dtype=np.float64)
    zipf = (1.0 / ranks) / np.sum(1.0 / ranks)
    for v in range(vocab):
        succ = rng.choice(vocab, size=BRANCH, replace=False)
        probs = rng.permutation(zipf)
        row = np.full(vocab, 1e-4, np.float64)
        row[succ] += probs
        chain[v] = (row / row.sum()).astype(np.float32)
    return chain


def sample_tokens(chain: np.ndarray, n: int, seed: int,
                  copy_p: float = COPY_P, copy_lag: int = COPY_LAG
                  ) -> np.ndarray:
    """Sample a token stream of length n (chain + copy rule)."""
    rng = np.random.default_rng(seed)
    vocab = chain.shape[0]
    out = np.empty(n, np.uint16)
    tok = rng.integers(vocab)
    cdf = np.cumsum(chain, axis=-1)
    for i in range(n):
        out[i] = tok
        if i + 1 >= copy_lag and rng.random() < copy_p:
            tok = int(out[i + 1 - copy_lag])
        else:
            u = rng.random()
            tok = int(np.searchsorted(cdf[tok], u))
            tok = min(tok, vocab - 1)
    return out


def shifted_chain(chain: np.ndarray, noise: float = PTB_NOISE) -> np.ndarray:
    """Interpolate with uniform — the 'PTB' out-of-domain distribution."""
    vocab = chain.shape[-1]
    uni = np.full_like(chain, 1.0 / vocab)
    mixed = (1.0 - noise) * chain + noise * uni
    return mixed / mixed.sum(-1, keepdims=True)


def next_token_dist(chain: np.ndarray, context: np.ndarray,
                    copy_p: float = COPY_P,
                    copy_lag: int = COPY_LAG) -> np.ndarray:
    """True predictive distribution for the token after ``context`` —
    used by tests to sanity-check model perplexity against the oracle."""
    vocab = chain.shape[0]
    dist = (1.0 - copy_p) * chain[int(context[-1])].astype(np.float64)
    if len(context) >= copy_lag:
        d = np.zeros(vocab)
        d[int(context[len(context) - copy_lag])] = 1.0
        dist = dist + copy_p * d
    else:
        dist = dist / dist.sum()
    return dist


def generate_all(out_dir: pathlib.Path, vocab: int, seed: int = 1234,
                 train_tokens: int = 400_000, eval_tokens: int = 40_000):
    """Build chain + all splits, write artifacts, return the train stream."""
    out_dir.mkdir(parents=True, exist_ok=True)
    chain = build_chain(vocab, seed)
    ptb = shifted_chain(chain)

    train = sample_tokens(chain, train_tokens, seed + 1)
    wiki = sample_tokens(chain, eval_tokens, seed + 2)
    ptb_s = sample_tokens(ptb, eval_tokens, seed + 3)
    alpaca = sample_tokens(chain, eval_tokens, seed + 4)

    chain.tofile(out_dir / "chain.bin")
    ptb.tofile(out_dir / "chain_ptb.bin")
    for name, arr in [("train", train), ("wiki", wiki), ("ptb", ptb_s),
                      ("alpaca", alpaca)]:
        arr.tofile(out_dir / f"{name}.bin")
    meta = {
        "vocab": vocab,
        "copy_p": COPY_P,
        "copy_lag": COPY_LAG,
        "seed": seed,
        "splits": {"train": train_tokens, "wiki": eval_tokens,
                   "ptb": eval_tokens, "alpaca": eval_tokens},
        "dtype": "u16",
        "chain_dtype": "f32",
    }
    (out_dir / "meta.json").write_text(json.dumps(meta, indent=2))
    return train
